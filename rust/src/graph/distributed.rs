//! Distributed graph shards: the per-locality slice of a partitioned graph.
//!
//! A [`Shard`] materializes a [`PartitionScheme`] at one locality. Its
//! local row space is dense and two-tiered:
//!
//! * rows `0..n_local` are the **owned** (master) vertices, ascending by
//!   global id — the authoritative state of every algorithm lives here;
//! * rows `n_local..n_local+n_ghosts` are **ghosts**: non-owned vertices
//!   referenced by locally homed edges, ascending by global id. The ghost
//!   table ([`Shard::ghost_global_ids`], [`Shard::ghost_owner`],
//!   [`Shard::ghost_master_index`]) precomputes, per ghost, the master
//!   locality and the dense owned-row index *at that master* — the wire
//!   index the [`Aggregator`](crate::amt::Aggregator) routes on. See the
//!   ghost-index invariants in [`partition`](super::partition).
//!
//! Adjacency is stored per local row for exactly the edges the scheme
//! homes here, behind [`AdjRows`] — either the historical flat arrays
//! (`storage=plain`, with a zero-copy global-id view) or delta-varint
//! compressed rows (`storage=compressed`, decoded through [`RowIter`] or
//! a caller-owned scratch buffer; see [`storage`](super::storage)).
//! Under 1-D schemes every edge lives with its source's master, so owned
//! rows carry whole rows and ghost rows carry nothing. Under a vertex
//! cut, ghost rows with locally homed out-edges are **mirrors**: the
//! master's [`Shard::mirrors`] table lists them as
//! `(locality, ghost-slot-at-that-locality)` pairs so an algorithm can
//! scatter a master update straight into the destination's dense row
//! space (gather-apply-scatter).
//!
//! The shard also keeps the full **in-CSR** of its owned rows (global
//! ids, used by pull-style engines) and, on demand, a **masked-ELL**
//! encoding of the in-adjacency ([`EllShard`]) with virtual-row splitting
//! for the AOT kernel path.
//!
//! Construction funnels through one seam: both the materialized path
//! ([`DistGraph::build_with_storage`], whole-graph [`Csr`] in hand) and
//! the streaming path ([`stream`](super::stream), no global graph ever
//! built) route per-locality edge triples into [`assemble_shard`], so
//! the two paths produce byte-identical shards.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

use super::mutation::{UpdateBatch, UpdateOp};
use super::partition::PartitionScheme;
use super::storage::{AdjRows, AdjRowsBuilder, AdjacencyStorage, RowIter, StorageKind};
use super::{Csr, Partition1D, VertexId};
use crate::amt::metrics::{MemStats, UpdateStats};
use crate::amt::sim::LocalityId;
use crate::amt::{Aggregator, FlushPolicy, NetConfig, SlotSpace};

/// One locality's shard. See the module docs for the row-space layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Shard {
    /// Owning locality.
    pub locality: LocalityId,
    /// Global ids of owned (master) rows, ascending; local row `r` of an
    /// owned vertex is its position here.
    pub owned_ids: Vec<VertexId>,
    /// Global out-degree of each owned row (PageRank contributions divide
    /// by this — the *global* degree, not the locally stored edge count).
    pub out_degree: Vec<u32>,
    /// Ghost table: global ids of non-owned local rows, ascending.
    pub ghost_global_ids: Vec<VertexId>,
    /// Master locality of each ghost.
    pub ghost_owner: Vec<LocalityId>,
    /// Dense owned-row index of each ghost at its master (the wire index).
    pub ghost_master_index: Vec<u32>,
    // Locally homed out-edges of owned rows. Canonical per-entry value is
    // the dense local target row; plain storage additionally keeps the
    // parallel global-id view (ascending per row).
    out_rows: AdjRows,
    out_weights: Vec<f32>, // empty when the graph is unweighted
    // Locally homed out-edges whose source is a ghost (mirror rows).
    ghost_rows: AdjRows,
    ghost_out_weights: Vec<f32>,
    // Mirror table: per owned row, every other locality holding out-edges
    // of that vertex, as (locality, ghost slot there).
    mirror_offsets: Vec<usize>,
    mirror_entries: Vec<(LocalityId, u32)>,
    // Full in-adjacency of owned rows (canonical value = global id).
    in_rows: AdjRows,
}

impl Shard {
    /// Number of owned vertices.
    pub fn n_local(&self) -> usize {
        self.owned_ids.len()
    }

    /// Number of ghost rows.
    pub fn n_ghosts(&self) -> usize {
        self.ghost_global_ids.len()
    }

    /// Total local rows (owned + ghosts).
    pub fn n_rows(&self) -> usize {
        self.n_local() + self.n_ghosts()
    }

    /// Which adjacency encoding this shard uses.
    pub fn storage(&self) -> StorageKind {
        self.out_rows.kind()
    }

    /// Global id of any local row (owned or ghost).
    pub fn global_of(&self, row: usize) -> VertexId {
        if row < self.n_local() {
            self.owned_ids[row]
        } else {
            self.ghost_global_ids[row - self.n_local()]
        }
    }

    /// Global id of an owned local row.
    pub fn global_id(&self, local: usize) -> VertexId {
        self.owned_ids[local]
    }

    /// Local row index of a global vertex (must be owned).
    pub fn local_index(&self, v: VertexId) -> usize {
        self.owned_ids.binary_search(&v).expect("vertex not owned by this shard")
    }

    /// Local row of a global vertex, owned or ghost; `None` if the shard
    /// never references it.
    pub fn row_of(&self, v: VertexId) -> Option<usize> {
        match self.owned_ids.binary_search(&v) {
            Ok(i) => Some(i),
            Err(_) => {
                self.ghost_global_ids.binary_search(&v).ok().map(|i| self.n_local() + i)
            }
        }
    }

    /// Out-neighbors (global ids, ascending) of the owned row `u` that
    /// are homed at this shard, as a slice. Plain storage returns its
    /// backing array (ignoring `scratch`); compressed storage decodes
    /// into `scratch` — callers own one scratch per hot loop and reuse
    /// it across rows. The result is sorted, so binary search works
    /// under either encoding.
    pub fn out_neighbors_into<'a>(
        &'a self,
        u: usize,
        scratch: &'a mut Vec<VertexId>,
    ) -> &'a [VertexId] {
        match self.out_rows.globals_slice(u) {
            Some(s) => s,
            None => {
                scratch.clear();
                scratch.extend(self.out_rows.iter_row(u).map(|t| self.global_of(t as usize)));
                scratch
            }
        }
    }

    /// Locally homed out-neighbors of any local row, as dense local rows
    /// (streaming decode; parallel to the global view of
    /// [`Shard::out_neighbors_into`] for owned rows). Owned rows read
    /// their row; ghost rows read their mirror adjacency (empty unless
    /// this shard homes edges of that vertex).
    pub fn row_locals(&self, row: usize) -> RowIter<'_> {
        if row < self.n_local() {
            self.out_rows.iter_row(row)
        } else {
            self.ghost_rows.iter_row(row - self.n_local())
        }
    }

    /// Locally homed out-edge count of any local row.
    pub fn row_len(&self, row: usize) -> usize {
        if row < self.n_local() {
            self.out_rows.row_len(row)
        } else {
            self.ghost_rows.row_len(row - self.n_local())
        }
    }

    /// Locally homed weighted out-edges of any local row as
    /// `(dense local target row, weight)`; unweighted graphs yield unit
    /// weights (SSSP on them degenerates to hop counts).
    pub fn row_edges(&self, row: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let (rows, weights, r) = if row < self.n_local() {
            (&self.out_rows, &self.out_weights, row)
        } else {
            (&self.ghost_rows, &self.ghost_out_weights, row - self.n_local())
        };
        let w = (!weights.is_empty()).then_some(weights.as_slice());
        let start = if w.is_some() { rows.entry_start(r) } else { 0 };
        rows.iter_row(r)
            .enumerate()
            .map(move |(k, t)| (t, w.map(|w| w[start + k]).unwrap_or(1.0)))
    }

    /// True when edge weights were carried over from the source graph.
    pub fn is_weighted(&self) -> bool {
        !self.out_weights.is_empty() || !self.ghost_out_weights.is_empty()
    }

    /// Mirror locations of the owned row `u`: every other locality that
    /// homes out-edges of this vertex, as (locality, ghost slot there).
    /// Empty under replication-free schemes.
    pub fn mirrors(&self, u: usize) -> &[(LocalityId, u32)] {
        &self.mirror_entries[self.mirror_offsets[u]..self.mirror_offsets[u + 1]]
    }

    /// True when any owned row has mirrors elsewhere.
    pub fn has_mirrors(&self) -> bool {
        !self.mirror_entries.is_empty()
    }

    /// In-neighbors (global ids, ascending) of the owned vertex with
    /// local row `u` — the *full* in-adjacency regardless of scheme, as
    /// a streaming decode (pull engines can break out early).
    pub fn in_neighbors_iter(&self, u: usize) -> RowIter<'_> {
        self.in_rows.iter_row(u)
    }

    /// In-neighbors of owned row `u` as a slice (zero-copy for plain
    /// storage, decoded into `scratch` for compressed).
    pub fn in_neighbors_into<'a>(
        &'a self,
        u: usize,
        scratch: &'a mut Vec<VertexId>,
    ) -> &'a [VertexId] {
        self.in_rows.row(u, scratch)
    }

    /// In-degree of owned row `u`.
    pub fn in_len(&self, u: usize) -> usize {
        self.in_rows.row_len(u)
    }

    /// Locally homed out-edge count (owned + mirror rows).
    pub fn m_out(&self) -> usize {
        self.out_rows.total_entries() + self.ghost_rows.total_entries()
    }

    /// Owned in-edge count.
    pub fn m_in(&self) -> usize {
        self.in_rows.total_entries()
    }

    /// Heap bytes this shard holds (adjacency, weights, ghost/mirror
    /// tables) — the per-locality cost [`MemStats`] aggregates.
    pub fn heap_bytes(&self) -> usize {
        use std::mem::size_of;
        self.owned_ids.len() * 4
            + self.out_degree.len() * 4
            + self.ghost_global_ids.len() * 4
            + self.ghost_owner.len() * size_of::<LocalityId>()
            + self.ghost_master_index.len() * 4
            + self.out_rows.heap_bytes()
            + self.ghost_rows.heap_bytes()
            + self.in_rows.heap_bytes()
            + (self.out_weights.len() + self.ghost_out_weights.len()) * 4
            + self.mirror_offsets.len() * size_of::<usize>()
            + self.mirror_entries.len() * size_of::<(LocalityId, u32)>()
    }

    /// The owned set as a contiguous global range, when it is one (1-D
    /// block/edge-balanced cuts). Pull engines that exchange contiguous
    /// slices (kernel PageRank) require this.
    pub fn contiguous_range(&self) -> Option<Range<usize>> {
        match (self.owned_ids.first(), self.owned_ids.last()) {
            (Some(&a), Some(&b)) if (b - a) as usize + 1 == self.owned_ids.len() => {
                Some(a as usize..b as usize + 1)
            }
            (None, _) => Some(0..0),
            _ => None,
        }
    }

    /// Copy per-owned-row values into their global slots.
    pub fn scatter_owned<T: Copy>(&self, local: &[T], global: &mut [T]) {
        debug_assert_eq!(local.len(), self.n_local());
        for (i, &gid) in self.owned_ids.iter().enumerate() {
            global[gid as usize] = local[i];
        }
    }

    /// Encode the in-adjacency as masked ELL with virtual-row splitting.
    ///
    /// * `max_deg` — slot width (must match the AOT artifact);
    /// * `pad_rows_to` — pad the virtual row count up to this (artifact
    ///   row count); `0` means no padding.
    ///
    /// Returns `None` if the virtual rows exceed `pad_rows_to`.
    pub fn in_ell(&self, max_deg: usize, pad_rows_to: usize) -> Option<EllShard> {
        let n_local = self.n_local();
        let mut row_map: Vec<u32> = Vec::new();
        let mut cols: Vec<i32> = Vec::new();
        let mut mask: Vec<f32> = Vec::new();
        let mut scratch: Vec<VertexId> = Vec::new();
        for u in 0..n_local {
            let nbrs = self.in_rows.row(u, &mut scratch);
            let chunks = if nbrs.is_empty() { 1 } else { nbrs.len().div_ceil(max_deg) };
            for c in 0..chunks {
                row_map.push(u as u32);
                let chunk = &nbrs[c * max_deg..((c + 1) * max_deg).min(nbrs.len())];
                for &v in chunk {
                    cols.push(v as i32);
                    mask.push(1.0);
                }
                for _ in chunk.len()..max_deg {
                    cols.push(0);
                    mask.push(0.0);
                }
            }
        }
        let n_virtual = row_map.len();
        let n_rows_padded = if pad_rows_to == 0 { n_virtual } else { pad_rows_to };
        if n_virtual > n_rows_padded {
            return None;
        }
        for _ in n_virtual..n_rows_padded {
            row_map.push(u32::MAX);
            cols.extend(std::iter::repeat(0).take(max_deg));
            mask.extend(std::iter::repeat(0.0).take(max_deg));
        }
        Some(EllShard { n_local, n_virtual, max_deg, n_rows_padded, cols, mask, row_map })
    }
}

/// Build one locality's [`Shard`] from its routed edges. This is the
/// construction seam both ingestion paths share:
///
/// * `homed` — the locally homed out-edges as `(src, tgt, weight)`
///   triples in `(src asc, tgt asc)` order (unit weights when
///   `!weighted`);
/// * `in_pairs` — the full in-adjacency of the owned set as
///   `(dst, src)` pairs sorted ascending.
///
/// The materialized and streaming builders produce these in identical
/// order, so shards are deeply equal across ingestion modes.
pub(crate) fn assemble_shard(
    l: LocalityId,
    owned_ids: Vec<VertexId>,
    out_degree: Vec<u32>,
    scheme: &dyn PartitionScheme,
    homed: &[(VertexId, VertexId, f32)],
    in_pairs: &[(VertexId, VertexId)],
    weighted: bool,
    kind: StorageKind,
) -> Shard {
    // Ghosts: every non-owned endpoint of a locally homed edge.
    let mut ghost: Vec<VertexId> = Vec::new();
    for &(u, v, _) in homed {
        if scheme.owner(u) != l {
            ghost.push(u);
        }
        if scheme.owner(v) != l {
            ghost.push(v);
        }
    }
    ghost.sort_unstable();
    ghost.dedup();
    let ghost_owner: Vec<LocalityId> = ghost.iter().map(|&v| scheme.owner(v)).collect();
    let ghost_master_index: Vec<u32> =
        ghost.iter().map(|&v| scheme.master_index(v) as u32).collect();
    let n_owned = owned_ids.len();
    let row_of = |v: VertexId| -> u32 {
        match owned_ids.binary_search(&v) {
            Ok(i) => i as u32,
            Err(_) => {
                let gi =
                    ghost.binary_search(&v).expect("edge endpoint neither owned nor ghost");
                (n_owned + gi) as u32
            }
        }
    };
    // Group homed triples by source (they arrive source-ascending).
    let mut groups: Vec<(VertexId, Range<usize>)> = Vec::new();
    let mut i = 0;
    while i < homed.len() {
        let src = homed[i].0;
        let mut j = i + 1;
        while j < homed.len() && homed[j].0 == src {
            j += 1;
        }
        groups.push((src, i..j));
        i = j;
    }
    let emit = |ids: &[VertexId]| -> (AdjRows, Vec<f32>) {
        let mut b = AdjRowsBuilder::new(kind, weighted, true);
        let mut wts = Vec::new();
        for &gid in ids {
            if let Ok(k) = groups.binary_search_by_key(&gid, |x| x.0) {
                for &(_, v, w) in &homed[groups[k].1.clone()] {
                    b.push(row_of(v), v);
                    if weighted {
                        wts.push(w);
                    }
                }
            }
            b.end_row();
        }
        (b.finish(), wts)
    };
    let (out_rows, out_weights) = emit(&owned_ids);
    let (ghost_rows, ghost_out_weights) = emit(&ghost);

    // In-CSR of the owned set from the sorted (dst, src) pairs.
    let mut b = AdjRowsBuilder::new(kind, false, false);
    let mut i = 0;
    for &gid in &owned_ids {
        while i < in_pairs.len() && in_pairs[i].0 == gid {
            b.push(in_pairs[i].1, in_pairs[i].1);
            i += 1;
        }
        b.end_row();
    }
    debug_assert_eq!(i, in_pairs.len(), "in-pair dst not owned by this shard");
    let in_rows = b.finish();

    Shard {
        locality: l,
        owned_ids,
        out_degree,
        ghost_global_ids: ghost,
        ghost_owner,
        ghost_master_index,
        out_rows,
        out_weights,
        ghost_rows,
        ghost_out_weights,
        mirror_offsets: Vec::new(),
        mirror_entries: Vec::new(),
        in_rows,
    }
}

/// Second construction pass: the mirror table. A ghost row holding
/// out-edges is a mirror; its master's row records (locality, ghost
/// slot). Shared by both ingestion paths.
pub(crate) fn finish_mirrors(shards: &mut [Shard], n: usize) {
    let mut per_vertex: Vec<Vec<(LocalityId, u32)>> = vec![Vec::new(); n];
    for s in shards.iter() {
        for gi in 0..s.n_ghosts() {
            if s.ghost_rows.row_len(gi) > 0 {
                per_vertex[s.ghost_global_ids[gi] as usize].push((s.locality, gi as u32));
            }
        }
    }
    for s in shards.iter_mut() {
        let mut offs = Vec::with_capacity(s.n_local() + 1);
        let mut entries = Vec::new();
        offs.push(0);
        for &gid in &s.owned_ids {
            entries.extend_from_slice(&per_vertex[gid as usize]);
            offs.push(entries.len());
        }
        s.mirror_offsets = offs;
        s.mirror_entries = entries;
    }
}

/// Masked-ELL in-adjacency for the kernel-offload path (layout contract
/// shared with `python/compile/model.py`).
#[derive(Debug, Clone)]
pub struct EllShard {
    /// Owned (real) rows.
    pub n_local: usize,
    /// Virtual rows before padding (>= n_local).
    pub n_virtual: usize,
    /// Slot width.
    pub max_deg: usize,
    /// Padded row count (artifact shape).
    pub n_rows_padded: usize,
    /// `n_rows_padded * max_deg` global column ids (padding -> 0).
    pub cols: Vec<i32>,
    /// `n_rows_padded * max_deg` slot validity (1.0 real, 0.0 padding).
    pub mask: Vec<f32>,
    /// Virtual row -> owned local row (`u32::MAX` for padding rows).
    pub row_map: Vec<u32>,
}

impl EllShard {
    /// Fold per-virtual-row values back into per-owned-row values
    /// (re-accumulating split rows).
    pub fn fold_rows(&self, virtual_vals: &[f32]) -> Vec<f32> {
        debug_assert_eq!(virtual_vals.len(), self.n_rows_padded);
        let mut out = vec![0.0f32; self.n_local];
        for (r, &owner) in self.row_map.iter().enumerate() {
            if owner != u32::MAX {
                out[owner as usize] += virtual_vals[r];
            }
        }
        out
    }
}

/// Partition-quality summary of a built [`DistGraph`], merged into
/// [`SimReport::partition`](crate::amt::SimReport) by algorithm drivers.
pub use crate::amt::metrics::PartitionStats;

/// One shard-bound edit, the wire unit [`DistGraph::apply_updates`]
/// scatter-routes through the [`Aggregator`]: the out-adjacency edit goes
/// to the locality homing the edge, the in-adjacency edit to the target's
/// master, and the global-degree edit to the source's master.
#[derive(Debug, Clone)]
enum EdgeEdit {
    OutInsert { u: VertexId, v: VertexId, w: f32 },
    OutRemove { u: VertexId, v: VertexId },
    InInsert { v: VertexId, u: VertexId },
    InRemove { v: VertexId, u: VertexId },
    Deg { u: VertexId, delta: i32 },
}

impl Default for EdgeEdit {
    fn default() -> Self {
        EdgeEdit::Deg { u: 0, delta: 0 }
    }
}

/// A graph partitioned into per-locality shards.
#[derive(Debug, Clone)]
pub struct DistGraph {
    /// The vertex/edge partition scheme.
    pub partition: Arc<dyn PartitionScheme>,
    /// One shard per locality.
    pub shards: Vec<Shard>,
    n: usize,
    m: usize,
    owned_counts: Vec<usize>,
    ghost_counts: Vec<usize>,
    mem: MemStats,
}

impl DistGraph {
    /// Partition `g` according to a 1-D contiguous partition (the
    /// historical entry point; see [`DistGraph::build_with`]).
    pub fn build(g: &Csr, partition: &Partition1D) -> Self {
        DistGraph::build_with(g, Arc::new(partition.clone()))
    }

    /// Partition `g` according to any [`PartitionScheme`] with plain
    /// adjacency storage.
    pub fn build_with(g: &Csr, scheme: Arc<dyn PartitionScheme>) -> Self {
        DistGraph::build_with_storage(g, scheme, StorageKind::Plain)
    }

    /// Partition `g` according to any [`PartitionScheme`], storing shard
    /// adjacency as `kind`. This is the materialized path: the whole
    /// graph is in memory, so [`MemStats::peak_builder_bytes`] counts the
    /// CSR plus the full routing buffers. The streaming path
    /// ([`stream::build_streamed`](super::stream::build_streamed)) never
    /// holds those.
    pub fn build_with_storage(
        g: &Csr,
        scheme: Arc<dyn PartitionScheme>,
        kind: StorageKind,
    ) -> Self {
        let started = Instant::now();
        assert_eq!(g.n(), scheme.n(), "scheme covers a different vertex count");
        let p = scheme.p();
        assert!(p > 0, "need at least one locality");
        let offsets = g.offsets();
        let targets = g.targets();
        let weights = g.weights();
        let weighted = weights.is_some();

        // Route every edge: homed triples in (src asc, tgt asc) order per
        // locality, and the transpose as (dst, src) pairs per dst-owner.
        let mut homed: Vec<Vec<(VertexId, VertexId, f32)>> = vec![Vec::new(); p as usize];
        let mut in_bufs: Vec<Vec<(VertexId, VertexId)>> = vec![Vec::new(); p as usize];
        for u in 0..g.n() {
            for e in offsets[u]..offsets[u + 1] {
                let v = targets[e];
                let w = weights.map_or(1.0, |ws| ws[e]);
                homed[scheme.edge_home(u as VertexId, e) as usize].push((u as VertexId, v, w));
                in_bufs[scheme.owner(v) as usize].push((v, u as VertexId));
            }
        }
        for b in &mut in_bufs {
            b.sort_unstable();
        }
        let peak = g.heap_bytes()
            + homed.iter().map(|h| h.len()).sum::<usize>()
                * std::mem::size_of::<(VertexId, VertexId, f32)>()
            + in_bufs.iter().map(|b| b.len()).sum::<usize>()
                * std::mem::size_of::<(VertexId, VertexId)>();

        let mut shards: Vec<Shard> = Vec::with_capacity(p as usize);
        for l in 0..p {
            let owned_ids = scheme.owned_vertices(l);
            let out_degree = owned_ids.iter().map(|&v| g.degree(v) as u32).collect();
            shards.push(assemble_shard(
                l,
                owned_ids,
                out_degree,
                scheme.as_ref(),
                &homed[l as usize],
                &in_bufs[l as usize],
                weighted,
                kind,
            ));
        }
        finish_mirrors(&mut shards, g.n());
        DistGraph::from_parts(scheme, shards, g.n(), g.m(), kind, peak, started)
    }

    /// Final wrap-up shared by both ingestion paths: per-locality counts
    /// plus the [`MemStats`] block.
    pub(crate) fn from_parts(
        scheme: Arc<dyn PartitionScheme>,
        shards: Vec<Shard>,
        n: usize,
        m: usize,
        kind: StorageKind,
        peak_builder_bytes: usize,
        started: Instant,
    ) -> Self {
        let owned_counts = shards.iter().map(Shard::n_local).collect();
        let ghost_counts = shards.iter().map(Shard::n_ghosts).collect();
        let total_shard_bytes: usize = shards.iter().map(Shard::heap_bytes).sum();
        let max_shard_bytes = shards.iter().map(Shard::heap_bytes).max().unwrap_or(0);
        let mem = MemStats {
            storage: kind.name(),
            total_shard_bytes,
            max_shard_bytes,
            bytes_per_edge: if m == 0 { 0.0 } else { total_shard_bytes as f64 / m as f64 },
            peak_builder_bytes,
            build_ms: started.elapsed().as_secs_f64() * 1e3,
        };
        DistGraph { partition: scheme, shards, n, m, owned_counts, ghost_counts, mem }
    }

    /// Convenience: block partition over `p` localities.
    pub fn block(g: &Csr, p: u32) -> Self {
        DistGraph::build(g, &Partition1D::block(g.n(), p))
    }

    /// Global vertex count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Global directed edge count.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Locality count.
    pub fn p(&self) -> u32 {
        self.partition.p()
    }

    /// Master of a global vertex (`vertex_locality_id` of Listing 1.2).
    pub fn owner(&self, v: VertexId) -> LocalityId {
        self.partition.owner(v)
    }

    /// Owned-row count per locality — the destination layout for
    /// master-bound [`Aggregator`](crate::amt::Aggregator)s.
    pub fn owned_counts(&self) -> &[usize] {
        &self.owned_counts
    }

    /// Ghost-row count per locality — the destination layout for
    /// mirror-bound (scatter) [`Aggregator`](crate::amt::Aggregator)s.
    pub fn ghost_counts(&self) -> &[usize] {
        &self.ghost_counts
    }

    /// True when any vertex has mirror rows (vertex-cut schemes). Engines
    /// without a scatter phase must reject such graphs.
    pub fn has_mirrors(&self) -> bool {
        self.shards.iter().any(|s| s.has_mirrors())
    }

    /// True when the shards carry edge weights.
    pub fn is_weighted(&self) -> bool {
        self.shards.iter().any(|s| s.is_weighted())
    }

    /// Storage footprint and build-cost stats, stamped into
    /// [`SimReport::mem`](crate::amt::SimReport) by algorithm drivers.
    pub fn mem_stats(&self) -> MemStats {
        self.mem
    }

    /// Partition-quality stats for [`SimReport`](crate::amt::SimReport):
    /// vertex/edge balance over the built shards plus the scheme's
    /// replication factor.
    pub fn partition_stats(&self) -> PartitionStats {
        let p = self.p() as f64;
        let v_mean = self.n as f64 / p;
        let e_mean = self.m as f64 / p;
        let v_max = self.shards.iter().map(|s| s.n_local() as f64).fold(0.0, f64::max);
        let e_max = self.shards.iter().map(|s| s.m_out() as f64).fold(0.0, f64::max);
        PartitionStats {
            vertex_imbalance: if v_mean == 0.0 { 1.0 } else { v_max / v_mean },
            edge_imbalance: if e_mean == 0.0 { 1.0 } else { e_max / e_mean },
            replication_factor: self.partition.replication_factor(),
        }
    }

    /// Apply an [`UpdateBatch`] to the live shards, in place.
    ///
    /// Semantics match [`mutation::apply_to_csr`](super::mutation::apply_to_csr)
    /// exactly (simple-graph, first-match, ops in order): an insert of a
    /// present edge and a delete of an absent one are no-ops. Effective
    /// ops are decomposed into three shard-bound edits — the out-edge at
    /// its home locality (inserts home at `owner(src)`; deletes wherever
    /// the instance lives, which differs under vertex cuts), the in-edge
    /// at `owner(dst)`, the global out-degree at `owner(src)` — and
    /// scatter-routed from locality 0 through a real [`Aggregator`] under
    /// `policy`, so update traffic is costed like any other remote
    /// action. Each touched shard then rebuilds through the same
    /// [`assemble_shard`] / [`finish_mirrors`] seam as initial ingestion,
    /// which keeps rows sorted, ghost tables minimal, and mirror tables
    /// globally consistent under every [`PartitionScheme`] and both
    /// storage kinds. Untouched shards are not rebuilt; `m`, ghost
    /// counts, and [`MemStats`] are restamped.
    ///
    /// Returns routing/application counters; the re-convergence fields of
    /// [`UpdateStats`] are filled by
    /// [`engine::incremental`](crate::engine) afterwards.
    pub fn apply_updates(
        &mut self,
        batch: &UpdateBatch,
        policy: FlushPolicy,
        net: &NetConfig,
    ) -> UpdateStats {
        let started = Instant::now();
        let mut stats = UpdateStats { batch_edges: batch.len() as u64, ..Default::default() };
        if batch.is_empty() {
            return stats;
        }
        let kind = self.shards[0].storage();
        let weighted = self.is_weighted();

        // Where each live edge instance is homed (multiset: one entry per
        // instance; vertex cuts spread a row across localities).
        let mut homes: HashMap<(VertexId, VertexId), Vec<LocalityId>> = HashMap::new();
        for s in &self.shards {
            for row in 0..s.n_rows() {
                let u = s.global_of(row);
                for t in s.row_locals(row) {
                    homes.entry((u, s.global_of(t as usize))).or_default().push(s.locality);
                }
            }
        }

        // Effective ops -> shard-bound edits, in batch order.
        let mut routed: Vec<(LocalityId, EdgeEdit)> = Vec::new();
        for op in &batch.ops {
            let (u, v) = (op.src, op.dst);
            assert!((u as usize) < self.n && (v as usize) < self.n, "update endpoint out of range");
            let instances = homes.entry((u, v)).or_default();
            match op.op {
                UpdateOp::Insert => {
                    if instances.is_empty() {
                        let home = self.partition.owner(u);
                        instances.push(home);
                        stats.applied += 1;
                        routed.push((home, EdgeEdit::OutInsert { u, v, w: op.weight }));
                        routed.push((self.partition.owner(v), EdgeEdit::InInsert { v, u }));
                        routed.push((home, EdgeEdit::Deg { u, delta: 1 }));
                    }
                }
                UpdateOp::Delete => {
                    if let Some(home) = instances.pop() {
                        stats.retracted += 1;
                        routed.push((home, EdgeEdit::OutRemove { u, v }));
                        routed.push((self.partition.owner(v), EdgeEdit::InRemove { v, u }));
                        routed.push((self.partition.owner(u), EdgeEdit::Deg { u, delta: -1 }));
                    }
                }
            }
        }
        stats.route_items = routed.len() as u64;
        if routed.is_empty() {
            self.mem.build_ms += started.elapsed().as_secs_f64() * 1e3;
            return stats;
        }

        // Scatter the edits through a real aggregator (origin: locality
        // 0). Slots are unique per destination so nothing folds, and the
        // per-destination slot order reconstructs batch order on arrival;
        // locality-0-bound edits bypass the wire like any local action.
        let p = self.p() as usize;
        let mut counts = vec![0usize; p];
        for &(dst, _) in &routed {
            counts[dst as usize] += 1;
        }
        fn clobber(_acc: &mut EdgeEdit, _new: EdgeEdit) {
            debug_assert!(false, "update routing uses unique slots; nothing may fold");
        }
        let mut agg = Aggregator::<EdgeEdit>::new(
            &counts,
            0,
            SlotSpace::Master,
            policy,
            net,
            std::mem::size_of::<EdgeEdit>(),
            clobber,
        );
        let mut delivered: Vec<Vec<(u32, EdgeEdit)>> = vec![Vec::new(); p];
        let mut next_slot = vec![0u32; p];
        for (dst, edit) in routed {
            let slot = next_slot[dst as usize];
            next_slot[dst as usize] += 1;
            if dst == 0 {
                delivered[0].push((slot, edit));
            } else if let Some(b) = agg.accumulate(dst, slot, edit, 0.0) {
                delivered[dst as usize].extend(b.into_items());
            }
        }
        for (dst, b) in agg.drain() {
            delivered[dst as usize].extend(b.into_items());
        }
        stats.route_envelopes = agg.stats().envelopes;
        for d in &mut delivered {
            d.sort_unstable_by_key(|&(slot, _)| slot);
        }

        // Re-derive each touched shard's construction inputs from its own
        // rows, splice the edits in (keeping the sorted invariants), and
        // rebuild through the shared ingestion seam.
        let scheme = self.partition.clone();
        let mut rebuild_peak = 0usize;
        for (l, edits) in delivered.into_iter().enumerate() {
            if edits.is_empty() {
                continue;
            }
            let s = &self.shards[l];
            let mut homed: Vec<(VertexId, VertexId, f32)> = Vec::with_capacity(s.m_out() + 1);
            for row in 0..s.n_rows() {
                let src = s.global_of(row);
                for (t, w) in s.row_edges(row) {
                    homed.push((src, s.global_of(t as usize), w));
                }
            }
            homed.sort_unstable_by_key(|e| (e.0, e.1));
            let mut in_pairs: Vec<(VertexId, VertexId)> = Vec::with_capacity(s.m_in() + 1);
            for u in 0..s.n_local() {
                let dst = s.global_id(u);
                for src in s.in_neighbors_iter(u) {
                    in_pairs.push((dst, src));
                }
            }
            let owned_ids = s.owned_ids.clone();
            let mut out_degree = s.out_degree.clone();
            for (_, edit) in edits {
                match edit {
                    EdgeEdit::OutInsert { u, v, w } => {
                        let at = homed.partition_point(|e| (e.0, e.1) < (u, v));
                        homed.insert(at, (u, v, w));
                    }
                    EdgeEdit::OutRemove { u, v } => {
                        let at = homed.partition_point(|e| (e.0, e.1) < (u, v));
                        debug_assert!(
                            at < homed.len() && (homed[at].0, homed[at].1) == (u, v),
                            "routed delete of ({u},{v}) missing at home {l}"
                        );
                        if at < homed.len() && (homed[at].0, homed[at].1) == (u, v) {
                            homed.remove(at);
                        }
                    }
                    EdgeEdit::InInsert { v, u } => {
                        let at = in_pairs.partition_point(|&e| e < (v, u));
                        in_pairs.insert(at, (v, u));
                    }
                    EdgeEdit::InRemove { v, u } => {
                        let at = in_pairs.partition_point(|&e| e < (v, u));
                        debug_assert!(
                            at < in_pairs.len() && in_pairs[at] == (v, u),
                            "routed in-delete of ({v},{u}) missing at owner {l}"
                        );
                        if at < in_pairs.len() && in_pairs[at] == (v, u) {
                            in_pairs.remove(at);
                        }
                    }
                    EdgeEdit::Deg { u, delta } => {
                        let i = owned_ids.binary_search(&u).expect("degree edit at non-owner");
                        out_degree[i] = (out_degree[i] as i64 + delta as i64).max(0) as u32;
                    }
                }
            }
            rebuild_peak += homed.len() * std::mem::size_of::<(VertexId, VertexId, f32)>()
                + in_pairs.len() * std::mem::size_of::<(VertexId, VertexId)>();
            self.shards[l] = assemble_shard(
                l as LocalityId,
                owned_ids,
                out_degree,
                scheme.as_ref(),
                &homed,
                &in_pairs,
                weighted,
                kind,
            );
        }
        finish_mirrors(&mut self.shards, self.n);

        self.m = (self.m as i64 + stats.applied as i64 - stats.retracted as i64) as usize;
        self.ghost_counts = self.shards.iter().map(Shard::n_ghosts).collect();
        let total: usize = self.shards.iter().map(Shard::heap_bytes).sum();
        self.mem.total_shard_bytes = total;
        self.mem.max_shard_bytes = self.shards.iter().map(Shard::heap_bytes).max().unwrap_or(0);
        self.mem.bytes_per_edge =
            if self.m == 0 { 0.0 } else { total as f64 / self.m as f64 };
        self.mem.peak_builder_bytes = self.mem.peak_builder_bytes.max(rebuild_peak);
        self.mem.build_ms += started.elapsed().as_secs_f64() * 1e3;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::graph::partition::PartitionKind;

    const KINDS: [StorageKind; 2] = [StorageKind::Plain, StorageKind::Compressed];

    #[test]
    fn shards_cover_all_edges() {
        let g = generators::urand(8, 4, 2);
        for kind in PartitionKind::all() {
            for storage in KINDS {
                let d = DistGraph::build_with_storage(&g, kind.build(&g, 4), storage);
                let out_total: usize = d.shards.iter().map(|s| s.m_out()).sum();
                let in_total: usize = d.shards.iter().map(|s| s.m_in()).sum();
                assert_eq!(out_total, g.m(), "{kind:?}/{storage:?}");
                assert_eq!(in_total, g.m(), "{kind:?}/{storage:?}");
            }
        }
    }

    #[test]
    fn shard_neighbors_match_global_graph() {
        let g = generators::kron(7, 4, 3);
        for storage in KINDS {
            let d = DistGraph::build_with_storage(
                &g,
                Arc::new(Partition1D::block(g.n(), 3)),
                storage,
            );
            let mut scratch = Vec::new();
            for s in &d.shards {
                assert_eq!(s.storage(), storage);
                for u in 0..s.n_local() {
                    let gu = s.global_id(u);
                    assert_eq!(s.out_neighbors_into(u, &mut scratch), g.neighbors(gu));
                    assert_eq!(s.out_degree[u] as usize, g.degree(gu));
                    assert_eq!(s.row_len(u), g.degree(gu));
                    // The local-index view resolves back to the same globals.
                    let back: Vec<VertexId> =
                        s.row_locals(u).map(|t| s.global_of(t as usize)).collect();
                    assert_eq!(back, g.neighbors(gu));
                }
            }
        }
    }

    #[test]
    fn ghost_tables_route_to_masters() {
        let g = generators::kron(7, 6, 9);
        for kind in PartitionKind::all() {
            let scheme = kind.build(&g, 4);
            let d = DistGraph::build_with(&g, scheme.clone());
            for s in &d.shards {
                assert!(
                    s.ghost_global_ids.windows(2).all(|w| w[0] < w[1]),
                    "{kind:?}: ghost ids not ascending"
                );
                for gi in 0..s.n_ghosts() {
                    let v = s.ghost_global_ids[gi];
                    assert!(s.owned_ids.binary_search(&v).is_err(), "ghost {v} also owned");
                    assert_eq!(s.ghost_owner[gi], scheme.owner(v), "{kind:?}");
                    assert_eq!(
                        s.ghost_master_index[gi] as usize,
                        scheme.master_index(v),
                        "{kind:?}"
                    );
                    assert_ne!(s.ghost_owner[gi], s.locality, "ghost owned by its own shard");
                }
            }
        }
    }

    #[test]
    fn mirror_tables_are_bidirectional() {
        let g = generators::kron(7, 6, 21);
        for storage in KINDS {
            let d = DistGraph::build_with_storage(
                &g,
                PartitionKind::VertexCut.build(&g, 4),
                storage,
            );
            let mut mirror_edges = 0usize;
            for s in &d.shards {
                for u in 0..s.n_local() {
                    for &(dst, gi) in s.mirrors(u) {
                        let peer = &d.shards[dst as usize];
                        // The mirror slot names the same global vertex and
                        // really holds edges of it.
                        assert_eq!(peer.ghost_global_ids[gi as usize], s.owned_ids[u]);
                        let row = peer.n_local() + gi as usize;
                        assert!(peer.row_len(row) > 0);
                    }
                }
                for gi in 0..s.n_ghosts() {
                    let row = s.n_local() + gi;
                    if s.row_len(row) > 0 {
                        mirror_edges += 1;
                        // This mirror must be listed at its master.
                        let owner = &d.shards[s.ghost_owner[gi] as usize];
                        let mrow = s.ghost_master_index[gi] as usize;
                        assert!(owner.mirrors(mrow).contains(&(s.locality, gi as u32)));
                    }
                }
            }
            assert!(mirror_edges > 0, "kron@4 vertex cut should produce mirrors");
            assert!(d.has_mirrors());
        }
        assert!(!DistGraph::block(&g, 4).has_mirrors());
    }

    #[test]
    fn every_scheme_covers_every_edge_exactly_once_locally() {
        // For each scheme, the union over shards of (global src, global
        // tgt) homed edges equals the graph's edge multiset.
        let g = generators::urand(6, 5, 33);
        for kind in PartitionKind::all() {
            for storage in KINDS {
                let d = DistGraph::build_with_storage(&g, kind.build(&g, 3), storage);
                let mut got: Vec<(VertexId, VertexId)> = Vec::new();
                for s in &d.shards {
                    for row in 0..s.n_rows() {
                        let src = s.global_of(row);
                        for t in s.row_locals(row) {
                            got.push((src, s.global_of(t as usize)));
                        }
                    }
                }
                got.sort_unstable();
                let mut want: Vec<(VertexId, VertexId)> = Vec::new();
                for u in 0..g.n() as VertexId {
                    for &v in g.neighbors(u) {
                        want.push((u, v));
                    }
                }
                want.sort_unstable();
                assert_eq!(got, want, "{kind:?}/{storage:?}");
            }
        }
    }

    #[test]
    fn weighted_edges_survive_sharding() {
        let g = generators::with_random_weights(&generators::urand(6, 4, 5), 1.0, 9.0, 6);
        for kind in PartitionKind::all() {
            for storage in KINDS {
                let d = DistGraph::build_with_storage(&g, kind.build(&g, 3), storage);
                assert!(d.is_weighted(), "{kind:?}/{storage:?}");
                let mut total = 0usize;
                let mut sum = 0.0f64;
                for s in &d.shards {
                    for row in 0..s.n_rows() {
                        for (_, w) in s.row_edges(row) {
                            assert!((1.0..9.0).contains(&w));
                            total += 1;
                            sum += w as f64;
                        }
                    }
                }
                assert_eq!(total, g.m(), "{kind:?}/{storage:?}");
                let want: f64 = (0..g.n() as VertexId)
                    .flat_map(|u| {
                        g.neighbors_weighted(u).map(|(_, w)| w as f64).collect::<Vec<_>>()
                    })
                    .sum();
                assert!((sum - want).abs() < 1e-3, "{kind:?}/{storage:?}");
            }
        }
    }

    #[test]
    fn in_neighbors_are_the_transpose() {
        let g = generators::urand_directed(6, 4, 5);
        let t = g.transpose();
        for storage in KINDS {
            let d = DistGraph::build_with_storage(
                &g,
                Arc::new(Partition1D::block(g.n(), 2)),
                storage,
            );
            let mut scratch = Vec::new();
            for s in &d.shards {
                for u in 0..s.n_local() {
                    let want = t.neighbors(s.global_id(u));
                    assert_eq!(s.in_neighbors_into(u, &mut scratch), want);
                    assert_eq!(s.in_neighbors_iter(u).collect::<Vec<_>>(), want);
                    assert_eq!(s.in_len(u), want.len());
                }
            }
        }
    }

    #[test]
    fn row_iteration_is_storage_invariant() {
        // Plain and compressed shards agree entry-for-entry on every row
        // view, for every scheme (the in-file smoke test; the full
        // matrix lives in tests/storage_props.rs).
        let g = generators::kron(7, 6, 9);
        for kind in PartitionKind::all() {
            let scheme = kind.build(&g, 4);
            let dp = DistGraph::build_with_storage(&g, scheme.clone(), StorageKind::Plain);
            let dc = DistGraph::build_with_storage(&g, scheme, StorageKind::Compressed);
            for (sp, sc) in dp.shards.iter().zip(&dc.shards) {
                assert_eq!(sp.owned_ids, sc.owned_ids);
                assert_eq!(sp.ghost_global_ids, sc.ghost_global_ids);
                for row in 0..sp.n_rows() {
                    assert_eq!(
                        sp.row_locals(row).collect::<Vec<_>>(),
                        sc.row_locals(row).collect::<Vec<_>>()
                    );
                    assert_eq!(
                        sp.row_edges(row).collect::<Vec<_>>(),
                        sc.row_edges(row).collect::<Vec<_>>()
                    );
                }
                for u in 0..sp.n_local() {
                    assert_eq!(
                        sp.in_neighbors_iter(u).collect::<Vec<_>>(),
                        sc.in_neighbors_iter(u).collect::<Vec<_>>()
                    );
                    assert_eq!(sp.mirrors(u), sc.mirrors(u));
                }
            }
        }
    }

    #[test]
    fn mem_stats_report_compression() {
        let g = generators::kron(10, 8, 7);
        let scheme = Arc::new(Partition1D::block(g.n(), 4));
        let dp = DistGraph::build_with_storage(&g, scheme.clone(), StorageKind::Plain);
        let dc = DistGraph::build_with_storage(&g, scheme, StorageKind::Compressed);
        let (pm, cm) = (dp.mem_stats(), dc.mem_stats());
        assert_eq!(pm.storage, "plain");
        assert_eq!(cm.storage, "compressed");
        assert!(pm.bytes_per_edge > 0.0 && cm.bytes_per_edge > 0.0);
        assert!(
            cm.total_shard_bytes < pm.total_shard_bytes,
            "compressed {} vs plain {}",
            cm.total_shard_bytes,
            pm.total_shard_bytes
        );
        assert!(pm.max_shard_bytes <= pm.total_shard_bytes);
        assert!(pm.peak_builder_bytes > 0);
        assert!(pm.build_ms >= 0.0);
        // Shard totals agree with the per-shard accessor.
        let sum: usize = dp.shards.iter().map(Shard::heap_bytes).sum();
        assert_eq!(sum, pm.total_shard_bytes);
    }

    #[test]
    fn contiguity_detection() {
        let g = generators::urand(6, 4, 8);
        let blk = DistGraph::block(&g, 3);
        for s in &blk.shards {
            assert!(s.contiguous_range().is_some());
        }
        let hash = DistGraph::build_with(&g, PartitionKind::Hash.build(&g, 3));
        assert!(
            hash.shards.iter().any(|s| s.contiguous_range().is_none()),
            "hash shards should not all be contiguous"
        );
    }

    #[test]
    fn partition_stats_are_sane() {
        let g = generators::kron(8, 6, 17);
        let blk = DistGraph::block(&g, 8);
        let st = blk.partition_stats();
        assert!(st.vertex_imbalance >= 1.0);
        assert!(st.edge_imbalance >= 1.0);
        assert_eq!(st.replication_factor, 1.0);
        let vc = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 8));
        let st = vc.partition_stats();
        assert!(st.replication_factor >= 1.0);
        assert!(
            st.edge_imbalance <= blk.partition_stats().edge_imbalance + 1e-9,
            "vertex cut must not be worse than block on skewed graphs"
        );
    }

    #[test]
    fn ell_roundtrip_preserves_spmv() {
        let g = generators::urand_directed(6, 6, 7);
        let d = DistGraph::block(&g, 2);
        let n = g.n();
        let contrib: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin().abs()).collect();
        for s in &d.shards {
            let max_deg = 4; // force row splitting
            let ell = s.in_ell(max_deg, 0).unwrap();
            assert!(ell.n_virtual >= s.n_local());
            // Virtual SpMV then fold == direct in-neighbor sums.
            let mut virt = vec![0.0f32; ell.n_rows_padded];
            for r in 0..ell.n_rows_padded {
                for k in 0..max_deg {
                    let idx = r * max_deg + k;
                    virt[r] += contrib[ell.cols[idx] as usize] * ell.mask[idx];
                }
            }
            let folded = ell.fold_rows(&virt);
            let mut scratch = Vec::new();
            for u in 0..s.n_local() {
                let want: f32 = s
                    .in_neighbors_into(u, &mut scratch)
                    .iter()
                    .map(|&v| contrib[v as usize])
                    .sum();
                assert!((folded[u] - want).abs() < 1e-4, "row {u}: {} vs {want}", folded[u]);
            }
        }
    }

    #[test]
    fn ell_padding_rows_are_inert() {
        let g = generators::path(10);
        let d = DistGraph::block(&g, 2);
        let ell = d.shards[0].in_ell(8, 16).unwrap();
        assert_eq!(ell.n_rows_padded, 16);
        for r in ell.n_virtual..16 {
            assert_eq!(ell.row_map[r], u32::MAX);
            for k in 0..8 {
                assert_eq!(ell.mask[r * 8 + k], 0.0);
            }
        }
    }

    #[test]
    fn ell_rejects_overflow() {
        let g = generators::star(100);
        let d = DistGraph::block(&g, 1);
        // star center has degree 99; with max_deg 4 that's 25 virtual rows
        // for row 0 alone — padding to 8 rows must fail.
        assert!(d.shards[0].in_ell(4, 8).is_none());
    }

    use crate::amt::{FlushPolicy, NetConfig};
    use crate::graph::mutation::{self, UpdateBatch};

    fn apply(d: &mut DistGraph, b: &UpdateBatch) -> UpdateStats {
        d.apply_updates(b, FlushPolicy::Adaptive, &NetConfig::default())
    }

    #[test]
    fn updated_shards_match_fresh_rebuild() {
        // After apply_updates, shards under 1-D schemes are deeply equal
        // to a from-scratch build of the oracle-updated graph (vertex
        // cuts may home inserted edges differently; covered by the
        // multiset check below).
        let g = generators::with_random_weights(&generators::urand(7, 4, 2), 1.0, 9.0, 3);
        let batch = mutation::generate_batch(&g, 0.1, 0.5, 5, true);
        let (g2, applied, retracted) = mutation::apply_to_csr(&g, &batch);
        for kind in [PartitionKind::Block, PartitionKind::EdgeBalanced, PartitionKind::Hash] {
            for storage in KINDS {
                let mut d = DistGraph::build_with_storage(&g, kind.build(&g, 4), storage);
                let st = apply(&mut d, &batch);
                assert_eq!((st.applied, st.retracted), (applied, retracted), "{kind:?}");
                assert_eq!(d.m(), g2.m(), "{kind:?}/{storage:?}");
                // Same scheme object: EdgeBalanced re-derived from g2
                // would move the vertex boundaries.
                let fresh = DistGraph::build_with_storage(&g2, d.partition.clone(), storage);
                assert_eq!(d.shards, fresh.shards, "{kind:?}/{storage:?}");
                assert_eq!(d.ghost_counts(), fresh.ghost_counts(), "{kind:?}/{storage:?}");
            }
        }
    }

    #[test]
    fn updated_edge_multiset_matches_oracle_under_all_schemes() {
        let g = generators::with_random_weights(&generators::kron(7, 5, 11), 1.0, 9.0, 4);
        let batch = mutation::generate_batch(&g, 0.08, 0.5, 9, true);
        let (g2, _, _) = mutation::apply_to_csr(&g, &batch);
        let mut want: Vec<(VertexId, VertexId, u32)> = Vec::new();
        for u in 0..g2.n() as VertexId {
            for (v, w) in g2.neighbors_weighted(u) {
                want.push((u, v, w.to_bits()));
            }
        }
        want.sort_unstable();
        for kind in PartitionKind::all() {
            for storage in KINDS {
                let mut d = DistGraph::build_with_storage(&g, kind.build(&g, 4), storage);
                apply(&mut d, &batch);
                let mut got: Vec<(VertexId, VertexId, u32)> = Vec::new();
                for s in &d.shards {
                    for row in 0..s.n_rows() {
                        let src = s.global_of(row);
                        for (t, w) in s.row_edges(row) {
                            got.push((src, s.global_of(t as usize), w.to_bits()));
                        }
                    }
                }
                got.sort_unstable();
                assert_eq!(got, want, "{kind:?}/{storage:?}");
                // In-CSR matches the transpose, degrees match the oracle.
                let t = g2.transpose();
                for s in &d.shards {
                    for u in 0..s.n_local() {
                        let gu = s.global_id(u);
                        assert_eq!(
                            s.in_neighbors_iter(u).collect::<Vec<_>>(),
                            t.neighbors(gu),
                            "{kind:?}/{storage:?} in-row of {gu}"
                        );
                        assert_eq!(
                            s.out_degree[u] as usize,
                            g2.degree(gu),
                            "{kind:?}/{storage:?} degree of {gu}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn mirror_tables_stay_consistent_after_updates() {
        let g = generators::kron(7, 6, 21);
        let batch = mutation::generate_batch(&g, 0.1, 0.5, 13, true);
        let mut d = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 4));
        apply(&mut d, &batch);
        for s in &d.shards {
            for u in 0..s.n_local() {
                for &(dst, gi) in s.mirrors(u) {
                    let peer = &d.shards[dst as usize];
                    assert_eq!(peer.ghost_global_ids[gi as usize], s.owned_ids[u]);
                    assert!(peer.row_len(peer.n_local() + gi as usize) > 0);
                }
            }
            for gi in 0..s.n_ghosts() {
                if s.row_len(s.n_local() + gi) > 0 {
                    let owner = &d.shards[s.ghost_owner[gi] as usize];
                    let mrow = s.ghost_master_index[gi] as usize;
                    assert!(owner.mirrors(mrow).contains(&(s.locality, gi as u32)));
                }
            }
        }
    }

    #[test]
    fn empty_and_noop_batches_change_nothing() {
        let g = generators::urand(6, 4, 8);
        let mut d = DistGraph::block(&g, 3);
        let before = d.shards.clone();
        let st = apply(&mut d, &UpdateBatch::new());
        assert_eq!((st.applied, st.retracted, st.route_items), (0, 0, 0));
        assert_eq!(d.shards, before);
        let mut noop = UpdateBatch::new();
        noop.insert(0, g.neighbors(0)[0], 1.0); // already present
        noop.delete(1, 1); // absent self-loop
        let st = apply(&mut d, &noop);
        assert_eq!((st.applied, st.retracted, st.route_items), (0, 0, 0));
        assert_eq!(d.shards, before);
        assert_eq!(d.m(), g.m());
    }

    #[test]
    fn update_routing_is_counted() {
        let g = generators::urand(7, 4, 2);
        let batch = mutation::generate_batch(&g, 0.2, 0.5, 5, true);
        let mut d = DistGraph::block(&g, 4);
        let st = apply(&mut d, &batch);
        assert_eq!(st.batch_edges, batch.len() as u64);
        assert_eq!(st.route_items, 3 * (st.applied + st.retracted));
        assert!(st.route_envelopes > 0, "p=4 must route some edits remotely");
        let mut single = DistGraph::block(&g, 1);
        let st1 = apply(&mut single, &batch);
        assert_eq!(st1.route_envelopes, 0, "p=1 routes everything locally");
        assert_eq!(st1.route_items, st.route_items);
    }
}
