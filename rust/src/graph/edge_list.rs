//! COO edge lists: the construction-time representation.

use super::VertexId;

/// A directed edge list over `n` vertices, with optional per-edge weights.
#[derive(Debug, Clone, Default)]
pub struct EdgeList {
    /// Vertex count (ids must be `< n`).
    pub n: usize,
    /// `(source, target)` pairs.
    pub edges: Vec<(VertexId, VertexId)>,
    /// Optional weights, parallel to `edges` (empty == unweighted).
    pub weights: Vec<f32>,
}

impl EdgeList {
    /// New empty edge list over `n` vertices.
    pub fn new(n: usize) -> Self {
        EdgeList { n, edges: Vec::new(), weights: Vec::new() }
    }

    /// From raw pairs.
    pub fn from_pairs(n: usize, pairs: impl IntoIterator<Item = (VertexId, VertexId)>) -> Self {
        let edges: Vec<_> = pairs.into_iter().collect();
        debug_assert!(edges.iter().all(|&(u, v)| (u as usize) < n && (v as usize) < n));
        EdgeList { n, edges, weights: Vec::new() }
    }

    /// Edge count.
    pub fn m(&self) -> usize {
        self.edges.len()
    }

    /// True when weighted.
    pub fn is_weighted(&self) -> bool {
        !self.weights.is_empty()
    }

    /// Add one edge.
    pub fn push(&mut self, u: VertexId, v: VertexId) {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        self.edges.push((u, v));
    }

    /// Add one weighted edge.
    pub fn push_weighted(&mut self, u: VertexId, v: VertexId, w: f32) {
        self.push(u, v);
        self.weights.resize(self.edges.len() - 1, 1.0);
        self.weights.push(w);
    }

    /// Drop self loops (in place).
    pub fn remove_self_loops(&mut self) {
        if self.is_weighted() {
            let mut kept_w = Vec::with_capacity(self.weights.len());
            let mut kept_e = Vec::with_capacity(self.edges.len());
            for (&(u, v), &w) in self.edges.iter().zip(&self.weights) {
                if u != v {
                    kept_e.push((u, v));
                    kept_w.push(w);
                }
            }
            self.edges = kept_e;
            self.weights = kept_w;
        } else {
            self.edges.retain(|&(u, v)| u != v);
        }
    }

    /// Sort and remove duplicate edges (keeping the first weight).
    pub fn dedup(&mut self) {
        if self.is_weighted() {
            let mut zipped: Vec<((VertexId, VertexId), f32)> =
                self.edges.iter().cloned().zip(self.weights.iter().cloned()).collect();
            zipped.sort_by_key(|&(e, _)| e);
            zipped.dedup_by_key(|&mut (e, _)| e);
            self.edges = zipped.iter().map(|&(e, _)| e).collect();
            self.weights = zipped.iter().map(|&(_, w)| w).collect();
        } else {
            self.edges.sort_unstable();
            self.edges.dedup();
        }
    }

    /// Add the reverse of every edge (directed → symmetric), then dedup.
    /// GAP's `urand` inputs are undirected; BFS-style traversals expect a
    /// symmetrized adjacency.
    pub fn symmetrize(&mut self) {
        let rev: Vec<(VertexId, VertexId)> =
            self.edges.iter().map(|&(u, v)| (v, u)).collect();
        if self.is_weighted() {
            let rev_w = self.weights.clone();
            self.edges.extend(rev);
            self.weights.extend(rev_w);
        } else {
            self.edges.extend(rev);
        }
        self.remove_self_loops();
        self.dedup();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_count() {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        assert_eq!(el.m(), 2);
        assert!(!el.is_weighted());
    }

    #[test]
    fn remove_self_loops_keeps_order() {
        let mut el = EdgeList::from_pairs(3, [(0, 1), (1, 1), (2, 0)]);
        el.remove_self_loops();
        assert_eq!(el.edges, vec![(0, 1), (2, 0)]);
    }

    #[test]
    fn dedup_removes_duplicates() {
        let mut el = EdgeList::from_pairs(3, [(2, 0), (0, 1), (0, 1), (2, 0)]);
        el.dedup();
        assert_eq!(el.edges, vec![(0, 1), (2, 0)]);
    }

    #[test]
    fn symmetrize_adds_reverses_and_cleans() {
        let mut el = EdgeList::from_pairs(3, [(0, 1), (1, 2), (2, 2)]);
        el.symmetrize();
        assert_eq!(el.edges, vec![(0, 1), (1, 0), (1, 2), (2, 1)]);
    }

    #[test]
    fn weighted_symmetrize_carries_weights() {
        let mut el = EdgeList::new(3);
        el.push_weighted(0, 1, 2.5);
        el.push_weighted(1, 2, 0.5);
        el.symmetrize();
        assert_eq!(el.m(), 4);
        assert_eq!(el.weights.len(), 4);
        let idx = el.edges.iter().position(|&e| e == (1, 0)).unwrap();
        assert_eq!(el.weights[idx], 2.5);
    }
}
