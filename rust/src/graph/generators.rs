//! Synthetic graph generators (GAP-style inputs).
//!
//! The paper evaluates on Erdős–Rényi "urand" graphs (`urandN` = 2^N
//! vertices; GAP benchmark naming). We provide `urand` plus the RMAT /
//! Kronecker family (GAP's `kron`) and structured graphs for tests and
//! examples. The paper's urand25 does not fit a laptop-scale run; the
//! benches use urand16–urand20 from the *same generator family*
//! (substitution table, DESIGN.md §4).

use super::{Csr, EdgeList, VertexId};

/// SplitMix64 — tiny, fast, reproducible PRNG (no external crates offline).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift; bias negligible for graph generation.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// Raw urand sampling: emit the `degree * 2^scale` uniformly random
/// directed pairs exactly as [`urand`]/[`urand_directed`] draw them
/// (self loops included — callers filter). The streaming ingester
/// ([`stream`](super::stream)) replays this without an [`EdgeList`].
pub fn sample_urand(scale: u32, degree: usize, seed: u64, mut emit: impl FnMut(VertexId, VertexId)) {
    let n = 1usize << scale;
    let mut rng = SplitMix64::new(seed);
    for _ in 0..n * degree {
        let u = rng.below(n as u64) as VertexId;
        let v = rng.below(n as u64) as VertexId;
        emit(u, v);
    }
}

/// Raw RMAT sampling: emit the quadrant-descent pairs exactly as
/// [`rmat`] draws them (self loops already dropped, duplicates kept).
pub fn sample_rmat(
    scale: u32,
    degree: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
    mut emit: impl FnMut(VertexId, VertexId),
) {
    let n = 1usize << scale;
    let d = 1.0 - a - b - c;
    assert!(d >= -1e-9, "rmat probabilities exceed 1");
    let mut rng = SplitMix64::new(seed);
    for _ in 0..n * degree {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        if u != v {
            emit(u as VertexId, v as VertexId);
        }
    }
}

/// Erdős–Rényi G(n, m) — the GAP "urand" model: `n = 2^scale` vertices,
/// `degree * n` uniformly random directed edges, then symmetrized (GAP
/// urand graphs are undirected), self loops and duplicates removed.
pub fn urand(scale: u32, degree: usize, seed: u64) -> Csr {
    let n = 1usize << scale;
    let mut el = EdgeList::new(n);
    el.edges.reserve(n * degree);
    sample_urand(scale, degree, seed, |u, v| el.push(u, v));
    el.symmetrize();
    Csr::from_edge_list(&el)
}

/// Directed Erdős–Rényi G(n, m) without symmetrization — used for PageRank
/// inputs where direction matters.
pub fn urand_directed(scale: u32, degree: usize, seed: u64) -> Csr {
    let n = 1usize << scale;
    let mut el = EdgeList::new(n);
    el.edges.reserve(n * degree);
    sample_urand(scale, degree, seed, |u, v| {
        if u != v {
            el.push(u, v);
        }
    });
    el.dedup();
    Csr::from_edge_list(&el)
}

/// RMAT / Kronecker generator (GAP `kron`): recursive quadrant descent with
/// probabilities `(a, b, c, d)`; the default (0.57, 0.19, 0.19, 0.05) is the
/// Graph500 parameterization, producing the skewed degree distributions the
/// paper's load-imbalance discussion targets.
pub fn rmat(scale: u32, degree: usize, a: f64, b: f64, c: f64, seed: u64) -> Csr {
    let n = 1usize << scale;
    let mut el = EdgeList::new(n);
    el.edges.reserve(n * degree);
    sample_rmat(scale, degree, a, b, c, seed, |u, v| el.push(u, v));
    el.symmetrize();
    Csr::from_edge_list(&el)
}

/// Graph500-parameterized kron graph.
pub fn kron(scale: u32, degree: usize, seed: u64) -> Csr {
    rmat(scale, degree, 0.57, 0.19, 0.19, seed)
}

/// Simple path 0-1-2-...-(n-1), undirected.
pub fn path(n: usize) -> Csr {
    let mut el = EdgeList::new(n);
    for i in 1..n {
        el.push((i - 1) as VertexId, i as VertexId);
        el.push(i as VertexId, (i - 1) as VertexId);
    }
    Csr::from_edge_list(&el)
}

/// Cycle over n vertices, undirected.
pub fn cycle(n: usize) -> Csr {
    let mut el = EdgeList::new(n);
    for i in 0..n {
        let j = (i + 1) % n;
        el.push(i as VertexId, j as VertexId);
        el.push(j as VertexId, i as VertexId);
    }
    el.dedup();
    Csr::from_edge_list(&el)
}

/// Star: vertex 0 connected to all others, undirected.
pub fn star(n: usize) -> Csr {
    let mut el = EdgeList::new(n);
    for i in 1..n {
        el.push(0, i as VertexId);
        el.push(i as VertexId, 0);
    }
    Csr::from_edge_list(&el)
}

/// 2-D grid `rows x cols`, undirected, row-major vertex ids.
pub fn grid(rows: usize, cols: usize) -> Csr {
    let n = rows * cols;
    let mut el = EdgeList::new(n);
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                el.push(id(r, c), id(r, c + 1));
                el.push(id(r, c + 1), id(r, c));
            }
            if r + 1 < rows {
                el.push(id(r, c), id(r + 1, c));
                el.push(id(r + 1, c), id(r, c));
            }
        }
    }
    Csr::from_edge_list(&el)
}

/// Complete graph on n vertices (no self loops).
pub fn complete(n: usize) -> Csr {
    let mut el = EdgeList::new(n);
    for u in 0..n {
        for v in 0..n {
            if u != v {
                el.push(u as VertexId, v as VertexId);
            }
        }
    }
    Csr::from_edge_list(&el)
}

/// Complete binary tree with n vertices (edges both directions).
pub fn binary_tree(n: usize) -> Csr {
    let mut el = EdgeList::new(n);
    for i in 1..n {
        let p = ((i - 1) / 2) as VertexId;
        el.push(p, i as VertexId);
        el.push(i as VertexId, p);
    }
    Csr::from_edge_list(&el)
}

/// Attach uniform-random weights in `[lo, hi)` to an unweighted graph
/// (symmetric edges get independent draws; fine for SSSP benchmarks).
pub fn with_random_weights(g: &Csr, lo: f32, hi: f32, seed: u64) -> Csr {
    let mut rng = SplitMix64::new(seed);
    let mut el = EdgeList::new(g.n());
    for u in 0..g.n() as VertexId {
        for &v in g.neighbors(u) {
            el.push_weighted(u, v, lo + (hi - lo) * rng.f64() as f32);
        }
    }
    Csr::from_edge_list(&el)
}

/// Attach uniform random weights in `[lo, hi)` keyed by the *undirected*
/// vertex pair, so `w(u, v) == w(v, u)` whenever both directions exist.
/// On a symmetrized graph this yields a symmetric shortest-path metric
/// (`d(s, t) == d(t, s)`), which the landmark oracle in
/// [`serve`](crate::serve) requires for its triangle-inequality bounds —
/// [`with_random_weights`] draws a fresh weight per directed CSR entry
/// and is *not* symmetric.
pub fn with_symmetric_random_weights(g: &Csr, lo: f32, hi: f32, seed: u64) -> Csr {
    let mut el = EdgeList::new(g.n());
    for u in 0..g.n() as VertexId {
        for &v in g.neighbors(u) {
            el.push_weighted(u, v, symmetric_weight(seed, lo, hi, u, v));
        }
    }
    Csr::from_edge_list(&el)
}

/// The pair-keyed weight draw behind [`with_symmetric_random_weights`]:
/// order-independent (`w(u,v) == w(v,u)`) and a pure function of the
/// pair, so the streaming ingester can stamp weights per edge without
/// any shared sequence state.
pub fn symmetric_weight(seed: u64, lo: f32, hi: f32, u: VertexId, v: VertexId) -> f32 {
    let (a, b) = if u <= v { (u, v) } else { (v, u) };
    // One independently-mixed draw per unordered pair: SplitMix64 is a
    // bijective mixer, so seeding with the pair key gives a
    // deterministic, well-distributed weight.
    let key = ((a as u64) << 32) | b as u64;
    let mut rng = SplitMix64::new(seed ^ key.wrapping_mul(0x9E3779B97F4A7C15));
    lo + (hi - lo) * rng.f64() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn urand_is_symmetric_and_loopless() {
        let g = urand(8, 4, 1);
        assert_eq!(g.n(), 256);
        for u in 0..g.n() as VertexId {
            for &v in g.neighbors(u) {
                assert_ne!(u, v, "self loop at {u}");
                assert!(g.has_edge(v, u), "asymmetric edge {u}->{v}");
            }
        }
        // Average degree in the right ballpark (2 * degree for symmetrized).
        let avg = g.m() as f64 / g.n() as f64;
        assert!(avg > 4.0 && avg < 9.0, "avg degree {avg}");
    }

    #[test]
    fn urand_same_seed_same_graph() {
        assert_eq!(urand(6, 4, 9), urand(6, 4, 9));
    }

    #[test]
    fn rmat_is_skewed() {
        let g = kron(10, 8, 3);
        let mut degs: Vec<usize> = (0..g.n() as VertexId).map(|u| g.degree(u)).collect();
        degs.sort_unstable();
        let max = *degs.last().unwrap();
        let med = degs[degs.len() / 2];
        assert!(max > 4 * med.max(1), "kron should be skewed: max={max} med={med}");
    }

    #[test]
    fn structured_shapes() {
        assert_eq!(path(5).m(), 8);
        assert_eq!(cycle(5).m(), 10);
        assert_eq!(star(5).m(), 8);
        assert_eq!(grid(3, 4).m(), 2 * (3 * 3 + 2 * 4));
        assert_eq!(complete(4).m(), 12);
        assert_eq!(binary_tree(7).m(), 12);
    }

    #[test]
    fn random_weights_in_range() {
        let g = with_random_weights(&path(10), 1.0, 2.0, 5);
        assert!(g.is_weighted());
        for u in 0..g.n() as VertexId {
            for (_, w) in g.neighbors_weighted(u) {
                assert!((1.0..2.0).contains(&w));
            }
        }
    }

    #[test]
    fn symmetric_weights_agree_across_directions() {
        let g = with_symmetric_random_weights(&urand(7, 4, 11), 1.0, 10.0, 13);
        assert!(g.is_weighted());
        let mut checked = 0u32;
        for u in 0..g.n() as VertexId {
            for (v, w) in g.neighbors_weighted(u) {
                assert!((1.0..10.0).contains(&w));
                let back: Vec<f32> =
                    g.neighbors_weighted(v).filter(|&(x, _)| x == u).map(|(_, w)| w).collect();
                assert!(!back.is_empty(), "urand is symmetrized: ({v},{u}) must exist");
                for bw in back {
                    assert_eq!(bw, w, "w({u},{v}) != w({v},{u})");
                }
                checked += 1;
            }
        }
        assert!(checked > 0);
    }

    #[test]
    fn symmetric_weights_are_deterministic() {
        let base = urand(6, 4, 21);
        let a = with_symmetric_random_weights(&base, 1.0, 10.0, 9);
        let b = with_symmetric_random_weights(&base, 1.0, 10.0, 9);
        for u in 0..a.n() as VertexId {
            let wa: Vec<(VertexId, f32)> = a.neighbors_weighted(u).collect();
            let wb: Vec<(VertexId, f32)> = b.neighbors_weighted(u).collect();
            assert_eq!(wa, wb);
        }
    }
}
