//! Graph I/O: whitespace edge lists and a MatrixMarket-pattern subset.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::{Csr, EdgeList, VertexId};
use crate::Result;

/// Parse a whitespace-separated edge list: one `u v [w]` per line, `#` or
/// `%` comments. Vertex count is `max id + 1` unless `n_hint` is larger.
pub fn read_edge_list<R: Read>(reader: R, n_hint: usize) -> Result<EdgeList> {
    let mut el = EdgeList::new(0);
    let mut max_id: usize = 0;
    let mut any_weight = false;
    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line?;
        let s = line.trim();
        if s.is_empty() || s.starts_with('#') || s.starts_with('%') {
            continue;
        }
        let mut it = s.split_whitespace();
        let u: usize = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing source", lineno + 1))?
            .parse()?;
        let v: usize = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing target", lineno + 1))?
            .parse()?;
        let w: Option<f32> = it.next().map(|t| t.parse()).transpose()?;
        max_id = max_id.max(u).max(v);
        el.edges.push((u as VertexId, v as VertexId));
        if let Some(w) = w {
            any_weight = true;
            el.weights.resize(el.edges.len() - 1, 1.0);
            el.weights.push(w);
        } else if any_weight {
            el.weights.push(1.0);
        }
    }
    el.n = (max_id + 1).max(n_hint);
    if el.edges.is_empty() {
        el.n = n_hint;
    }
    Ok(el)
}

/// Read an edge-list file (see [`read_edge_list`]).
pub fn read_edge_list_file(path: impl AsRef<Path>) -> Result<EdgeList> {
    let f = std::fs::File::open(path.as_ref())?;
    read_edge_list(f, 0)
}

/// Write a graph as an edge list (`u v` or `u v w` lines).
pub fn write_edge_list<W: Write>(g: &Csr, writer: W) -> Result<()> {
    let mut w = BufWriter::new(writer);
    writeln!(w, "# nwgraph-hpx edge list: n={} m={}", g.n(), g.m())?;
    if g.is_weighted() {
        for u in 0..g.n() as VertexId {
            for (v, wt) in g.neighbors_weighted(u) {
                writeln!(w, "{u} {v} {wt}")?;
            }
        }
    } else {
        for u in 0..g.n() as VertexId {
            for &v in g.neighbors(u) {
                writeln!(w, "{u} {v}")?;
            }
        }
    }
    Ok(())
}

/// Read a MatrixMarket `coordinate pattern` / `coordinate real` file as a
/// directed graph (1-based indices per the format).
pub fn read_matrix_market<R: Read>(reader: R) -> Result<EdgeList> {
    let mut lines = BufReader::new(reader).lines();
    let header = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty MatrixMarket file"))??;
    if !header.starts_with("%%MatrixMarket") {
        anyhow::bail!("not a MatrixMarket file: {header}");
    }
    let symmetric = header.contains("symmetric");
    let mut dims: Option<(usize, usize)> = None;
    let mut el = EdgeList::new(0);
    for line in lines {
        let line = line?;
        let s = line.trim();
        if s.is_empty() || s.starts_with('%') {
            continue;
        }
        let mut it = s.split_whitespace();
        if dims.is_none() {
            let rows: usize = it.next().unwrap().parse()?;
            let cols: usize = it.next().unwrap().parse()?;
            dims = Some((rows, cols));
            el.n = rows.max(cols);
            continue;
        }
        let u: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad entry"))?.parse()?;
        let v: usize = it.next().ok_or_else(|| anyhow::anyhow!("bad entry"))?.parse()?;
        let (u, v) = (u - 1, v - 1); // 1-based -> 0-based
        el.push(u as VertexId, v as VertexId);
        if symmetric && u != v {
            el.push(v as VertexId, u as VertexId);
        }
    }
    if dims.is_none() {
        anyhow::bail!("MatrixMarket file has no size line");
    }
    Ok(el)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn edge_list_roundtrip() {
        let g = generators::urand(6, 4, 1);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let el = read_edge_list(&buf[..], g.n()).unwrap();
        let g2 = Csr::from_edge_list(&el);
        assert_eq!(g, g2);
    }

    #[test]
    fn weighted_roundtrip() {
        let g = generators::with_random_weights(&generators::path(6), 1.0, 2.0, 3);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let el = read_edge_list(&buf[..], g.n()).unwrap();
        assert!(el.is_weighted());
        let g2 = Csr::from_edge_list(&el);
        for u in 0..g.n() as VertexId {
            let a: Vec<_> = g.neighbors_weighted(u).collect();
            let b: Vec<_> = g2.neighbors_weighted(u).collect();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let text = "# comment\n\n% other comment\n0 1\n1 2\n";
        let el = read_edge_list(text.as_bytes(), 0).unwrap();
        assert_eq!(el.edges, vec![(0, 1), (1, 2)]);
        assert_eq!(el.n, 3);
    }

    #[test]
    fn matrix_market_symmetric() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n\
                    % a triangle\n3 3 3\n1 2\n2 3\n1 3\n";
        let el = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(el.n, 3);
        assert_eq!(el.m(), 6);
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_matrix_market("nope".as_bytes()).is_err());
        assert!(read_edge_list("0\n".as_bytes(), 0).is_err());
    }
}
