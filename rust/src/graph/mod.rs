//! NWGraph-equivalent graph library.
//!
//! NWGraph models a graph as a "range of ranges" — an outer range of
//! vertices, each with an inner range of neighbors — and builds generic
//! algorithms on that concept (paper §3.1). The same shape here:
//! [`Csr`] is the range-of-ranges workhorse, [`EdgeList`] the builder
//! input, [`generators`] produce the GAP-style synthetic inputs
//! (`urand`, RMAT/Kronecker, structured families), the
//! [`PartitionScheme`] implementations ([`Partition1D`], [`Hash1D`],
//! [`VertexCut2D`]) and [`DistGraph`] carve a graph into per-locality
//! shards (with ghost/mirror tables for vertex cuts) for the simulated
//! runtime, and [`views`] provide NWGraph-style traversal ranges.
//! [`storage`] makes shard adjacency pluggable (plain arrays or
//! delta-varint compressed rows) and [`stream`] builds shards from an
//! edge stream without ever materializing the global graph. [`mutation`]
//! adds dynamic-graph edge-update batches that
//! [`DistGraph::apply_updates`] applies to the live shards.

pub mod builder;
pub mod csr;
pub mod degree;
pub mod distributed;
pub mod edge_list;
pub mod generators;
pub mod io;
pub mod mutation;
pub mod partition;
pub mod storage;
pub mod stream;
pub mod views;

pub use csr::Csr;
pub use distributed::{DistGraph, EllShard, Shard};
pub use edge_list::EdgeList;
pub use mutation::{EdgeUpdate, UpdateBatch, UpdateOp};
pub use partition::{Hash1D, Partition1D, PartitionKind, PartitionScheme, VertexCut2D};
pub use storage::{AdjacencyStorage, CompressedCsr, StorageKind};
pub use stream::EdgeSource;

/// Vertex identifier (global index space).
pub type VertexId = u32;
