//! Edge-update batches for dynamic graphs.
//!
//! The paper's algorithms all run over a static partitioned graph; this
//! module adds the vocabulary for *mutating* that graph after it has been
//! distributed: an [`UpdateBatch`] of edge inserts/deletes, a seeded
//! generator that derives realistic batches from an existing [`Csr`]
//! (deletes sampled from live edges, inserts rejection-sampled from absent
//! pairs), and a sequential oracle [`apply_to_csr`] whose semantics
//! [`DistGraph::apply_updates`](super::DistGraph::apply_updates) must
//! match exactly. The incremental re-convergence machinery in
//! [`engine::incremental`](crate::engine::incremental) consumes both
//! sides: the distributed apply mutates the shards in place, the oracle
//! apply produces the reference graph that validation recomputes on.
//!
//! Semantics are **simple-graph, first-match**: inserting an edge that
//! already exists is a no-op, deleting an edge that does not exist is a
//! no-op, and deleting an edge that exists removes exactly one instance
//! (the first in `(src, dst)`-sorted order). Ops inside a batch are
//! applied in order, so `insert(u,v); delete(u,v)` on an absent edge nets
//! to nothing and both count (one applied, one retracted).

use std::collections::{HashMap, HashSet};

use super::generators::{symmetric_weight, SplitMix64};
use super::{Csr, EdgeList, VertexId};

/// What a single [`EdgeUpdate`] does to the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateOp {
    /// Add the edge (no-op if it already exists).
    Insert,
    /// Remove one instance of the edge (no-op if absent).
    Delete,
}

/// One directed edge insert or delete. `weight` is the weight a
/// successful insert materializes on weighted graphs; it is ignored for
/// deletes and on unweighted graphs (which store unit weights).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdgeUpdate {
    pub src: VertexId,
    pub dst: VertexId,
    pub weight: f32,
    pub op: UpdateOp,
}

/// An ordered batch of edge updates, applied atomically between two
/// program runs. Order matters only for ops touching the same `(src,
/// dst)` pair; the generator never emits such conflicts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct UpdateBatch {
    pub ops: Vec<EdgeUpdate>,
}

impl UpdateBatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, src: VertexId, dst: VertexId, weight: f32) {
        self.ops.push(EdgeUpdate { src, dst, weight, op: UpdateOp::Insert });
    }

    pub fn delete(&mut self, src: VertexId, dst: VertexId) {
        self.ops.push(EdgeUpdate { src, dst, weight: 1.0, op: UpdateOp::Delete });
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// Map `(src, dst)` to how many instances the graph currently holds.
fn edge_multiset(g: &Csr) -> HashMap<(VertexId, VertexId), u32> {
    let mut m = HashMap::with_capacity(g.m());
    for u in 0..g.n() as VertexId {
        for &v in g.neighbors(u) {
            *m.entry((u, v)).or_insert(0) += 1;
        }
    }
    m
}

/// Derive a seeded update batch from `g`: roughly `frac * m` edge pairs
/// (directed edges when `symmetric` is false, undirected pairs — emitted
/// as both directions with equal weight — when true), an `insert_share`
/// fraction of which are inserts of currently-absent non-loop pairs and
/// the rest deletes of live edges. Deletes are sampled uniformly from the
/// edge array; inserts are rejection-sampled from absent pairs, so dense
/// graphs may yield fewer inserts than requested. Insert weights come
/// from [`symmetric_weight`] in `[1, 10)` when `g` is weighted. `frac ==
/// 0` yields an empty batch; any positive `frac` yields at least one
/// pair when the graph has edges. Deletes are emitted before inserts and
/// no pair appears twice, so the batch is order-insensitive.
pub fn generate_batch(
    g: &Csr,
    frac: f64,
    insert_share: f64,
    seed: u64,
    symmetric: bool,
) -> UpdateBatch {
    let n = g.n() as u64;
    let m = g.m();
    let mut batch = UpdateBatch::new();
    if n < 2 || frac <= 0.0 {
        return batch;
    }
    let pairs = if symmetric { m / 2 } else { m };
    let target = ((frac * pairs as f64).round() as usize).max(1);
    let n_inserts = ((insert_share.clamp(0.0, 1.0) * target as f64).round() as usize).min(target);
    let n_deletes = target - n_inserts;

    let mut rng = SplitMix64::new(seed);
    let mut chosen: HashSet<(VertexId, VertexId)> = HashSet::with_capacity(target);
    let offsets = g.offsets();
    let src_of = |e: usize| -> VertexId {
        // offsets is monotone with offsets[u] <= e < offsets[u + 1].
        (offsets.partition_point(|&o| o <= e) - 1) as VertexId
    };

    let budget = 32 * target + 64;
    let mut tries = 0;
    while chosen.len() < n_deletes && m > 0 && tries < budget {
        tries += 1;
        let e = rng.below(m as u64) as usize;
        let (mut u, mut v) = (src_of(e), g.targets()[e]);
        if symmetric {
            if u == v {
                continue;
            }
            if u > v {
                std::mem::swap(&mut u, &mut v);
            }
        }
        if chosen.insert((u, v)) {
            batch.delete(u, v);
            if symmetric {
                batch.delete(v, u);
            }
        }
    }

    let weighted = g.is_weighted();
    let mut inserted = 0;
    tries = 0;
    while inserted < n_inserts && tries < budget {
        tries += 1;
        let (mut u, mut v) = (rng.below(n) as VertexId, rng.below(n) as VertexId);
        if u == v {
            continue;
        }
        if symmetric && u > v {
            std::mem::swap(&mut u, &mut v);
        }
        if g.has_edge(u, v) || !chosen.insert((u, v)) {
            continue;
        }
        let w = if weighted { symmetric_weight(seed ^ 0x9e3779b97f4a7c15, 1.0, 10.0, u, v) } else { 1.0 };
        batch.insert(u, v, w);
        if symmetric {
            batch.insert(v, u, w);
        }
        inserted += 1;
    }
    batch
}

/// Sequential-oracle counterpart of
/// [`DistGraph::apply_updates`](super::DistGraph::apply_updates): apply
/// `batch` to a plain [`Csr`] and return `(updated, applied, retracted)`
/// where `applied` counts effective inserts and `retracted` effective
/// deletes. Validation rebuilds algorithm answers on the returned graph
/// and cross-checks the counts against
/// [`UpdateStats`](crate::amt::UpdateStats).
pub fn apply_to_csr(g: &Csr, batch: &UpdateBatch) -> (Csr, u64, u64) {
    let weighted = g.is_weighted();
    let mut counts = edge_multiset(g);
    let mut added: Vec<(VertexId, VertexId, f32)> = Vec::new();
    // (src, dst) -> how many leading instances to drop when rebuilding.
    let mut removed: HashMap<(VertexId, VertexId), u32> = HashMap::new();
    let (mut applied, mut retracted) = (0u64, 0u64);

    for op in &batch.ops {
        let key = (op.src, op.dst);
        let count = counts.entry(key).or_insert(0);
        match op.op {
            UpdateOp::Insert => {
                if *count == 0 {
                    *count = 1;
                    added.push((op.src, op.dst, op.weight));
                    applied += 1;
                }
            }
            UpdateOp::Delete => {
                if *count > 0 {
                    *count -= 1;
                    retracted += 1;
                    // A delete may retract an edge added earlier in this
                    // batch; cancel the pending add before recording a
                    // removal against the original graph.
                    if let Some(i) = added.iter().position(|&(u, v, _)| (u, v) == key) {
                        added.remove(i);
                    } else {
                        *removed.entry(key).or_insert(0) += 1;
                    }
                }
            }
        }
    }

    let mut el = EdgeList::new(g.n());
    for u in 0..g.n() as VertexId {
        for (v, w) in g.neighbors_weighted(u) {
            if let Some(k) = removed.get_mut(&(u, v)) {
                if *k > 0 {
                    *k -= 1;
                    continue;
                }
            }
            if weighted {
                el.push_weighted(u, v, w);
            } else {
                el.push(u, v);
            }
        }
    }
    for (u, v, w) in added {
        if weighted {
            el.push_weighted(u, v, w);
        } else {
            el.push(u, v);
        }
    }
    (Csr::from_edge_list(&el), applied, retracted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn edge_set(g: &Csr) -> HashSet<(VertexId, VertexId)> {
        let mut s = HashSet::new();
        for u in 0..g.n() as VertexId {
            for &v in g.neighbors(u) {
                s.insert((u, v));
            }
        }
        s
    }

    #[test]
    fn insert_and_delete_roundtrip() {
        let g = generators::path(5);
        let mut b = UpdateBatch::new();
        b.insert(0, 4, 1.0);
        b.delete(1, 2);
        let (g2, applied, retracted) = apply_to_csr(&g, &b);
        assert_eq!((applied, retracted), (1, 1));
        assert_eq!(g2.m(), g.m());
        assert!(g2.has_edge(0, 4));
        assert!(!g2.has_edge(1, 2));
        assert!(g2.has_edge(2, 1), "only the requested direction is removed");
    }

    #[test]
    fn noop_ops_do_not_change_graph() {
        let g = generators::cycle(6);
        let mut b = UpdateBatch::new();
        b.insert(0, 1, 1.0); // already present
        b.delete(2, 5); // absent
        let (g2, applied, retracted) = apply_to_csr(&g, &b);
        assert_eq!((applied, retracted), (0, 0));
        assert_eq!(edge_set(&g2), edge_set(&g));
    }

    #[test]
    fn insert_then_delete_nets_to_nothing() {
        let g = generators::path(4);
        let mut b = UpdateBatch::new();
        b.insert(0, 3, 2.5);
        b.delete(0, 3);
        let (g2, applied, retracted) = apply_to_csr(&g, &b);
        assert_eq!((applied, retracted), (1, 1));
        assert_eq!(edge_set(&g2), edge_set(&g));
    }

    #[test]
    fn weighted_insert_keeps_weights() {
        let g = generators::path(4);
        let gw = generators::with_random_weights(&g, 1.0, 10.0, 7);
        let mut b = UpdateBatch::new();
        b.insert(0, 3, 4.25);
        let (g2, applied, _) = apply_to_csr(&gw, &b);
        assert_eq!(applied, 1);
        assert!(g2.is_weighted());
        let w = g2
            .neighbors_weighted(0)
            .find(|&(v, _)| v == 3)
            .map(|(_, w)| w)
            .unwrap();
        assert_eq!(w, 4.25);
        // untouched weights survive
        let old: Vec<_> = gw.neighbors_weighted(1).collect();
        let new: Vec<_> = g2.neighbors_weighted(1).collect();
        assert_eq!(old, new);
    }

    #[test]
    fn generated_batch_is_valid_and_seeded() {
        let g = generators::urand(8, 4, 42);
        let b1 = generate_batch(&g, 0.2, 0.5, 9, true);
        let b2 = generate_batch(&g, 0.2, 0.5, 9, true);
        assert_eq!(b1, b2, "same seed, same batch");
        assert!(!b1.is_empty());
        let mut seen = HashSet::new();
        for op in &b1.ops {
            assert_ne!(op.src, op.dst, "no self loops");
            assert!(seen.insert((op.src, op.dst)), "no duplicate directed ops");
            match op.op {
                UpdateOp::Delete => assert!(g.has_edge(op.src, op.dst)),
                UpdateOp::Insert => assert!(!g.has_edge(op.src, op.dst)),
            }
        }
        // symmetric batches carry both directions of every pair
        for op in &b1.ops {
            assert!(seen.contains(&(op.dst, op.src)));
        }
        let b3 = generate_batch(&g, 0.2, 0.5, 10, true);
        assert_ne!(b1, b3, "different seed, different batch");
    }

    #[test]
    fn generated_batch_applies_cleanly() {
        let g = generators::kron(6, 4, 11);
        let b = generate_batch(&g, 0.1, 0.5, 3, true);
        let (g2, applied, retracted) = apply_to_csr(&g, &b);
        // every op in a generated batch is effective
        assert_eq!(applied + retracted, b.len() as u64);
        assert_eq!(g2.m(), g.m() + applied as usize - retracted as usize);
    }

    #[test]
    fn zero_fraction_is_empty() {
        let g = generators::urand(6, 3, 1);
        assert!(generate_batch(&g, 0.0, 0.5, 1, true).is_empty());
    }

    #[test]
    fn directed_batch_has_single_directions() {
        let g = generators::urand_directed(8, 4, 5);
        let b = generate_batch(&g, 0.2, 1.0, 2, false);
        for op in &b.ops {
            assert_eq!(op.op, UpdateOp::Insert);
            assert!(!g.has_edge(op.src, op.dst));
        }
    }
}
