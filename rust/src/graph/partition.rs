//! Pluggable vertex partitioning across localities.
//!
//! # The [`PartitionScheme`] contract
//!
//! A scheme assigns every global vertex exactly one **master** locality
//! ([`PartitionScheme::owner`], the paper's `vertex_locality_id` from
//! Listing 1.2) and every directed edge exactly one **home** locality
//! ([`PartitionScheme::edge_home`], the locality that stores and expands
//! it). Within its master, each vertex has a dense **master index**
//! ([`PartitionScheme::master_index`]): the rank of the vertex among its
//! master's owned set in ascending global-id order. Master indices are
//! what travels on the wire — the [`Aggregator`](crate::amt::Aggregator)
//! combines updates per destination slot `master_index(v)`, so routing
//! never depends on the scheme being contiguous.
//!
//! 1-D schemes ([`Partition1D`] block / edge-balanced cuts, [`Hash1D`])
//! home every out-edge with its source's master: shards hold whole rows
//! and no vertex is replicated. [`VertexCut2D`] instead assigns *edges*
//! greedily (PowerGraph-style, degree-based tie-breaking), so a
//! high-degree vertex's row is split across localities: the non-master
//! copies are **mirrors**, and [`PartitionScheme::replication_factor`]
//! reports the mean number of copies per vertex.
//!
//! # Ghost-index invariants
//!
//! [`Shard`](super::Shard) materializes the scheme per locality. Its
//! ghost table obeys, for every ghost slot `i` of every shard:
//!
//! * `ghost_owner[i] == scheme.owner(ghost_global_ids[i])` — ghosts route
//!   to their master, never to another mirror;
//! * `ghost_master_index[i] == scheme.master_index(ghost_global_ids[i])`
//!   — the precomputed wire index is exactly the destination's dense
//!   owned-row index, so a receiver applies batch items directly with no
//!   translation;
//! * `ghost_global_ids` is strictly ascending and disjoint from the
//!   shard's owned set — local row `r` means owned row `r` when
//!   `r < n_local`, ghost `r - n_local` otherwise;
//! * every locally homed edge endpoint is addressable: it is either an
//!   owned row or a ghost slot.
//!
//! Algorithms therefore address neighbors by dense local index regardless
//! of the scheme, and remote updates flow sender-ghost-slot →
//! master-index → owner, with mirror scatter (master → every mirror of
//! the vertex) closing the gather-apply-scatter loop for vertex cuts.

use std::sync::Arc;

use super::{Csr, VertexId};
use crate::amt::agas::BlockMap;
use crate::amt::sim::LocalityId;

/// A vertex/edge-to-locality assignment. See the module docs for the full
/// contract. Implementations must be deterministic: the same graph and
/// locality count always produce the same assignment.
pub trait PartitionScheme: std::fmt::Debug + Send + Sync {
    /// Scheme name as spelled in config files (`block`, `hash`, ...).
    fn name(&self) -> &'static str;

    /// Locality count.
    fn p(&self) -> u32;

    /// Total vertex count covered.
    fn n(&self) -> usize;

    /// Master locality of vertex `v` (`vertex_locality_id` of Listing 1.2).
    fn owner(&self, v: VertexId) -> LocalityId;

    /// Dense index of `v` within its master's owned set (ascending
    /// global-id order). This is the wire index remote updates carry.
    fn master_index(&self, v: VertexId) -> usize;

    /// Number of vertices mastered at `l`.
    fn owned_count(&self, l: LocalityId) -> usize;

    /// Vertices mastered at `l`, ascending.
    fn owned_vertices(&self, l: LocalityId) -> Vec<VertexId>;

    /// Home locality of the out-edge with global CSR index `e` (source
    /// `u`). 1-D schemes home every edge with its source's master.
    fn edge_home(&self, u: VertexId, e: usize) -> LocalityId {
        let _ = e;
        self.owner(u)
    }

    /// Mean number of copies (master + mirrors) per vertex; 1.0 for
    /// replication-free schemes.
    fn replication_factor(&self) -> f64 {
        1.0
    }

    /// Owned-vertex count per locality, in locality order — the
    /// destination layout handed to
    /// [`Aggregator::new`](crate::amt::Aggregator::new).
    fn owned_counts(&self) -> Vec<usize> {
        (0..self.p()).map(|l| self.owned_count(l)).collect()
    }

    /// Max / mean owned-vertex count (vertex balance factor, >= 1.0).
    fn vertex_imbalance(&self) -> f64 {
        let p = self.p();
        let mean = self.n() as f64 / p as f64;
        if mean == 0.0 {
            return 1.0;
        }
        (0..p).map(|l| self.owned_count(l) as f64).fold(0.0, f64::max) / mean
    }

    /// Max / mean stored-edge count under `g` (edge balance factor,
    /// >= 1.0), computed from [`PartitionScheme::edge_home`].
    fn edge_imbalance(&self, g: &Csr) -> f64 {
        let mean = g.m() as f64 / self.p() as f64;
        if mean == 0.0 {
            return 1.0;
        }
        let mut per_loc = vec![0u64; self.p() as usize];
        let offsets = g.offsets();
        for u in 0..g.n() {
            for e in offsets[u]..offsets[u + 1] {
                per_loc[self.edge_home(u as VertexId, e) as usize] += 1;
            }
        }
        per_loc.iter().map(|&c| c as f64).fold(0.0, f64::max) / mean
    }
}

/// Which [`PartitionScheme`] to build — the `partition` config/CLI key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionKind {
    /// Equal-size contiguous blocks (the paper's layout).
    #[default]
    Block,
    /// Contiguous cuts balancing out-edges per locality.
    EdgeBalanced,
    /// Deterministic hash of the vertex id (1-D, non-contiguous).
    Hash,
    /// Greedy 2-D vertex cut (PowerGraph-style edge assignment).
    VertexCut,
}

impl PartitionKind {
    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Option<PartitionKind> {
        match s {
            "block" => Some(PartitionKind::Block),
            "edge_balanced" | "edge-balanced" => Some(PartitionKind::EdgeBalanced),
            "hash" => Some(PartitionKind::Hash),
            "vertex_cut" | "vertex-cut" | "2d" => Some(PartitionKind::VertexCut),
            _ => None,
        }
    }

    /// Config spelling of this kind.
    pub fn name(&self) -> &'static str {
        match self {
            PartitionKind::Block => "block",
            PartitionKind::EdgeBalanced => "edge_balanced",
            PartitionKind::Hash => "hash",
            PartitionKind::VertexCut => "vertex_cut",
        }
    }

    /// Every kind, in sweep order (ablation A6 / property suites).
    pub fn all() -> [PartitionKind; 4] {
        [
            PartitionKind::Block,
            PartitionKind::EdgeBalanced,
            PartitionKind::Hash,
            PartitionKind::VertexCut,
        ]
    }

    /// Build the scheme for `g` over `p` localities.
    pub fn build(&self, g: &Csr, p: u32) -> Arc<dyn PartitionScheme> {
        match self {
            PartitionKind::Block => Arc::new(Partition1D::block(g.n(), p)),
            PartitionKind::EdgeBalanced => Arc::new(Partition1D::edge_balanced(g, p)),
            PartitionKind::Hash => Arc::new(Hash1D::new(g.n(), p)),
            PartitionKind::VertexCut => Arc::new(VertexCut2D::new(g, p)),
        }
    }
}

/// A contiguous 1-D partition of `0..n` into `P` ranges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition1D {
    /// `starts[l]..starts[l+1]` is locality `l`'s range; `len == P + 1`.
    starts: Vec<usize>,
    /// Whether the cuts were edge-balanced (reporting only).
    edge_balanced: bool,
}

impl Partition1D {
    /// Equal-size block partition (HPX `container_layout` convention via
    /// [`BlockMap`]).
    pub fn block(n: usize, p: u32) -> Self {
        let map = BlockMap::new(n, p);
        let mut starts = Vec::with_capacity(p as usize + 1);
        starts.push(0);
        for l in 0..p {
            starts.push(map.range_of(l).end);
        }
        Partition1D { starts, edge_balanced: false }
    }

    /// Edge-balanced contiguous partition: cuts chosen so each locality
    /// owns roughly `m / P` out-edges. Mitigates the load imbalance from
    /// skewed degree distributions (paper §2).
    ///
    /// Degenerate inputs are handled deterministically: when `P > n`, or
    /// when a prefix plateau (a run of zero-degree vertices, or a single
    /// vertex holding more than `m / P` edges) makes consecutive cuts
    /// equal, the surplus localities get empty ranges — cuts are computed
    /// in integer arithmetic (`l * m / P`, no float rounding) and clamped
    /// monotone, so the result is always a valid cover.
    pub fn edge_balanced(g: &Csr, p: u32) -> Self {
        Partition1D::edge_balanced_cuts(g.offsets(), g.n(), g.m(), p)
    }

    /// Edge-balanced cuts from a degree array alone — the streaming
    /// ingester's entry point (it has per-vertex degrees but no CSR).
    /// Identical cuts to [`Partition1D::edge_balanced`] on the same graph.
    pub fn edge_balanced_from_degrees(degrees: &[u32], p: u32) -> Self {
        let n = degrees.len();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut acc = 0usize;
        offsets.push(0);
        for &d in degrees {
            acc += d as usize;
            offsets.push(acc);
        }
        Partition1D::edge_balanced_cuts(&offsets, n, acc, p)
    }

    fn edge_balanced_cuts(offsets: &[usize], n: usize, m: usize, p: u32) -> Self {
        let mut starts = Vec::with_capacity(p as usize + 1);
        starts.push(0);
        for l in 1..p as usize {
            // Integer target: first vertex whose edge prefix reaches l*m/P.
            let want = (l as u128 * m as u128 / p as u128) as usize;
            let cut = offsets.partition_point(|&o| o < want).min(n);
            let prev = *starts.last().unwrap();
            starts.push(cut.max(prev)); // keep monotone
        }
        starts.push(n);
        Partition1D { starts, edge_balanced: true }
    }

    /// From explicit cut points (must start at 0, end at n, be monotone).
    pub fn from_starts(starts: Vec<usize>) -> Self {
        assert!(starts.len() >= 2);
        assert_eq!(starts[0], 0);
        assert!(starts.windows(2).all(|w| w[0] <= w[1]));
        Partition1D { starts, edge_balanced: false }
    }

    /// Locality count.
    pub fn p(&self) -> u32 {
        (self.starts.len() - 1) as u32
    }

    /// Total vertex count covered.
    pub fn n(&self) -> usize {
        *self.starts.last().unwrap()
    }

    /// The paper's `vertex_locality_id`: owner of vertex `v`.
    pub fn owner(&self, v: VertexId) -> LocalityId {
        let v = v as usize;
        debug_assert!(v < self.n());
        // partition_point returns the first start > v; owner is that - 1.
        (self.starts.partition_point(|&s| s <= v) - 1) as LocalityId
    }

    /// Vertex range owned by locality `l`.
    pub fn range_of(&self, l: LocalityId) -> std::ops::Range<usize> {
        let l = l as usize;
        self.starts[l]..self.starts[l + 1]
    }

    /// Number of vertices owned by `l`.
    pub fn len_of(&self, l: LocalityId) -> usize {
        let r = self.range_of(l);
        r.end - r.start
    }

    /// Every locality's owned range, in locality order.
    pub fn ranges(&self) -> Vec<std::ops::Range<usize>> {
        (0..self.p()).map(|l| self.range_of(l)).collect()
    }

    /// Max / mean owned-vertex count (vertex balance factor).
    pub fn vertex_imbalance(&self) -> f64 {
        let p = self.p();
        let mean = self.n() as f64 / p as f64;
        if mean == 0.0 {
            return 1.0;
        }
        (0..p).map(|l| self.len_of(l) as f64).fold(0.0, f64::max) / mean
    }

    /// Max / mean owned-edge count under graph `g` (edge balance factor).
    pub fn edge_imbalance(&self, g: &Csr) -> f64 {
        let p = self.p();
        let mean = g.m() as f64 / p as f64;
        if mean == 0.0 {
            return 1.0;
        }
        let offsets = g.offsets();
        (0..p)
            .map(|l| {
                let r = self.range_of(l);
                (offsets[r.end] - offsets[r.start]) as f64
            })
            .fold(0.0, f64::max)
            / mean
    }
}

impl PartitionScheme for Partition1D {
    fn name(&self) -> &'static str {
        if self.edge_balanced {
            "edge_balanced"
        } else {
            "block"
        }
    }

    fn p(&self) -> u32 {
        Partition1D::p(self)
    }

    fn n(&self) -> usize {
        Partition1D::n(self)
    }

    fn owner(&self, v: VertexId) -> LocalityId {
        Partition1D::owner(self, v)
    }

    fn master_index(&self, v: VertexId) -> usize {
        let l = Partition1D::owner(self, v);
        v as usize - self.starts[l as usize]
    }

    fn owned_count(&self, l: LocalityId) -> usize {
        self.len_of(l)
    }

    fn owned_vertices(&self, l: LocalityId) -> Vec<VertexId> {
        self.range_of(l).map(|v| v as VertexId).collect()
    }

    fn vertex_imbalance(&self) -> f64 {
        Partition1D::vertex_imbalance(self)
    }

    fn edge_imbalance(&self, g: &Csr) -> f64 {
        Partition1D::edge_imbalance(self, g)
    }
}

/// SplitMix64 finalizer — a stateless avalanche mix for [`Hash1D`].
fn mix64(v: u64) -> u64 {
    let mut z = v.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// 1-D hash partition: `owner(v) = mix64(v) % P`. Non-contiguous but
/// replication-free; spreads hubs of skewed graphs uniformly at the price
/// of destroying all range locality.
#[derive(Debug, Clone)]
pub struct Hash1D {
    n: usize,
    p: u32,
    /// Dense index of each vertex within its owner's owned set.
    master_index: Vec<u32>,
    counts: Vec<usize>,
}

impl Hash1D {
    /// Hash-partition `n` vertices over `p` localities.
    pub fn new(n: usize, p: u32) -> Self {
        assert!(p > 0, "need at least one locality");
        let mut counts = vec![0usize; p as usize];
        let mut master_index = vec![0u32; n];
        for (v, mi) in master_index.iter_mut().enumerate() {
            let l = (mix64(v as u64) % p as u64) as usize;
            *mi = counts[l] as u32;
            counts[l] += 1;
        }
        Hash1D { n, p, master_index, counts }
    }
}

impl PartitionScheme for Hash1D {
    fn name(&self) -> &'static str {
        "hash"
    }

    fn p(&self) -> u32 {
        self.p
    }

    fn n(&self) -> usize {
        self.n
    }

    fn owner(&self, v: VertexId) -> LocalityId {
        debug_assert!((v as usize) < self.n);
        (mix64(v as u64) % self.p as u64) as LocalityId
    }

    fn master_index(&self, v: VertexId) -> usize {
        self.master_index[v as usize] as usize
    }

    fn owned_count(&self, l: LocalityId) -> usize {
        self.counts[l as usize]
    }

    fn owned_vertices(&self, l: LocalityId) -> Vec<VertexId> {
        (0..self.n as VertexId).filter(|&v| self.owner(v) == l).collect()
    }
}

/// Greedy 2-D vertex cut: edges are assigned to localities one by one
/// (CSR order), PowerGraph-style —
///
/// 1. if the endpoints' replica sets intersect, pick the least
///    edge-loaded locality in the intersection;
/// 2. else if both endpoints already have replicas, pick from the
///    higher-degree endpoint's set (the degree-based heuristic: the
///    heavier vertex has more future edges to co-locate);
/// 3. else if one endpoint has replicas, pick from its set;
/// 4. else pick the least edge-loaded locality overall.
///
/// A **load cap** keeps the balance bound constructive: when the
/// candidate set's best locality is more than `max(1, m/8P)` edges above
/// the global minimum, the edge spills to the globally least-loaded
/// locality instead (splitting the row — this is what shears hub rows
/// apart). No locality ever exceeds `min + cap + 1` while receiving
/// edges, so the final edge imbalance is at most `~1 + 1/8 + P/m`
/// regardless of skew — the bound the kron acceptance test relies on.
///
/// Each vertex's **master** is its least vertex-loaded replica (ties to
/// the smallest locality id); isolated vertices fall back to the block
/// layout. The construction is fully deterministic.
#[derive(Debug, Clone)]
pub struct VertexCut2D {
    n: usize,
    p: u32,
    owner: Vec<LocalityId>,
    master_index: Vec<u32>,
    counts: Vec<usize>,
    /// Home locality per global CSR edge index.
    edge_home: Vec<LocalityId>,
    replication: f64,
}

impl VertexCut2D {
    /// Build the greedy cut of `g` over `p` localities (`p <= 64`).
    pub fn new(g: &Csr, p: u32) -> Self {
        let degrees: Vec<u32> = (0..g.n()).map(|u| g.degree(u as VertexId) as u32).collect();
        let offsets = g.offsets();
        let targets = g.targets();
        VertexCut2D::from_parts(
            g.n(),
            p,
            &degrees,
            (0..g.n()).flat_map(|u| {
                targets[offsets[u]..offsets[u + 1]].iter().map(move |&v| (u as VertexId, v))
            }),
        )
    }

    /// Build the greedy cut from per-vertex degrees and an edge stream in
    /// global CSR order (`u` ascending, targets in row order) — the
    /// streaming ingester's entry point. Identical construction to
    /// [`VertexCut2D::new`] on the materialized graph.
    pub fn from_parts(
        n: usize,
        p: u32,
        degrees: &[u32],
        edges: impl Iterator<Item = (VertexId, VertexId)>,
    ) -> Self {
        assert!(p > 0, "need at least one locality");
        assert!(p <= 64, "VertexCut2D supports at most 64 localities, got {p}");
        assert_eq!(degrees.len(), n);
        let m: usize = degrees.iter().map(|&d| d as usize).sum();
        let all_mask: u64 = u64::MAX >> (64 - p);
        let cap = (m / (8 * p as usize)).max(1);
        let mut replicas = vec![0u64; n];
        let mut load = vec![0usize; p as usize];
        let mut edge_home = vec![0 as LocalityId; m];
        for (e, (gu, gv)) in edges.enumerate() {
            {
                let (u, v) = (gu as usize, gv as usize);
                let du = degrees[u] as usize;
                let (ru, rv) = (replicas[u], replicas[v]);
                let both = ru & rv;
                let cand = if both != 0 {
                    both
                } else if ru != 0 && rv != 0 {
                    if du >= degrees[v] as usize {
                        ru
                    } else {
                        rv
                    }
                } else if ru != 0 {
                    ru
                } else if rv != 0 {
                    rv
                } else {
                    all_mask
                };
                let mut best = 0u32;
                let mut best_load = usize::MAX;
                let mut global_best = 0u32;
                let mut global_load = usize::MAX;
                for l in 0..p {
                    let ld = load[l as usize];
                    if cand >> l & 1 == 1 && ld < best_load {
                        best = l;
                        best_load = ld;
                    }
                    if ld < global_load {
                        global_best = l;
                        global_load = ld;
                    }
                }
                if best_load > global_load + cap {
                    // Load cap: spill to the global minimum, splitting the
                    // row — keeps the balance bound constructive.
                    best = global_best;
                }
                edge_home[e] = best;
                load[best as usize] += 1;
                replicas[u] |= 1 << best;
                replicas[v] |= 1 << best;
            }
        }
        // Masters: least vertex-loaded replica; block fallback for
        // isolated vertices (empty replica set).
        let block = BlockMap::new(n, p);
        let mut vload = vec![0usize; p as usize];
        let mut owner = vec![0 as LocalityId; n];
        for v in 0..n {
            let mask = replicas[v];
            let l = if mask == 0 {
                block.resolve(v).locality
            } else {
                let mut best = 0u32;
                let mut best_load = usize::MAX;
                for l in 0..p {
                    if mask >> l & 1 == 1 && vload[l as usize] < best_load {
                        best = l;
                        best_load = vload[l as usize];
                    }
                }
                best
            };
            owner[v] = l;
            vload[l as usize] += 1;
        }
        let mut counts = vec![0usize; p as usize];
        let mut master_index = vec![0u32; n];
        for v in 0..n {
            let l = owner[v] as usize;
            master_index[v] = counts[l] as u32;
            counts[l] += 1;
        }
        let total_copies: u64 = (0..n)
            .map(|v| u64::from((replicas[v] | 1u64 << owner[v]).count_ones()))
            .sum();
        let replication = if n == 0 { 1.0 } else { total_copies as f64 / n as f64 };
        VertexCut2D { n, p, owner, master_index, counts, edge_home, replication }
    }
}

impl PartitionScheme for VertexCut2D {
    fn name(&self) -> &'static str {
        "vertex_cut"
    }

    fn p(&self) -> u32 {
        self.p
    }

    fn n(&self) -> usize {
        self.n
    }

    fn owner(&self, v: VertexId) -> LocalityId {
        self.owner[v as usize]
    }

    fn master_index(&self, v: VertexId) -> usize {
        self.master_index[v as usize] as usize
    }

    fn owned_count(&self, l: LocalityId) -> usize {
        self.counts[l as usize]
    }

    fn owned_vertices(&self, l: LocalityId) -> Vec<VertexId> {
        (0..self.n as VertexId).filter(|&v| self.owner[v as usize] == l).collect()
    }

    fn edge_home(&self, _u: VertexId, e: usize) -> LocalityId {
        self.edge_home[e]
    }

    fn replication_factor(&self) -> f64 {
        self.replication
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    /// Exhaustive consistency check shared by every scheme's tests.
    fn check_scheme(s: &dyn PartitionScheme, g: &Csr) {
        let (n, p) = (s.n(), s.p());
        assert_eq!(n, g.n());
        // Masters partition the vertex set; master indices are dense
        // ascending per locality.
        let mut seen = vec![false; n];
        for l in 0..p {
            let owned = s.owned_vertices(l);
            assert_eq!(owned.len(), s.owned_count(l));
            assert!(owned.windows(2).all(|w| w[0] < w[1]), "owned set not ascending");
            for (i, &v) in owned.iter().enumerate() {
                assert_eq!(s.owner(v), l);
                assert_eq!(s.master_index(v), i);
                assert!(!seen[v as usize], "vertex {v} owned twice");
                seen[v as usize] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "some vertex has no master");
        // Edge homes are valid localities.
        let offsets = g.offsets();
        for u in 0..n {
            for e in offsets[u]..offsets[u + 1] {
                assert!(s.edge_home(u as VertexId, e) < p);
            }
        }
        // Quality metrics are well-formed.
        assert!(s.replication_factor() >= 1.0 - 1e-12);
        assert!(s.vertex_imbalance() >= 1.0 - 1e-9);
        assert!(s.edge_imbalance(g) >= 1.0 - 1e-9);
    }

    #[test]
    fn block_partition_owner_matches_range() {
        let p = Partition1D::block(10, 3); // 4,3,3
        assert_eq!(p.range_of(0), 0..4);
        assert_eq!(p.range_of(1), 4..7);
        assert_eq!(p.range_of(2), 7..10);
        for v in 0..10u32 {
            let l = p.owner(v);
            assert!(p.range_of(l).contains(&(v as usize)));
            assert_eq!(PartitionScheme::master_index(&p, v), v as usize - p.range_of(l).start);
        }
    }

    #[test]
    fn partitions_cover_everything_once() {
        for (n, k) in [(10usize, 3u32), (1, 4), (100, 7), (64, 64)] {
            let p = Partition1D::block(n, k);
            let total: usize = (0..k).map(|l| p.len_of(l)).sum();
            assert_eq!(total, n);
        }
    }

    #[test]
    fn edge_balanced_beats_block_on_skewed_graphs() {
        let g = generators::kron(10, 8, 11);
        let blk = Partition1D::block(g.n(), 8);
        let bal = Partition1D::edge_balanced(&g, 8);
        assert!(
            bal.edge_imbalance(&g) <= blk.edge_imbalance(&g) + 1e-9,
            "balanced {} vs block {}",
            bal.edge_imbalance(&g),
            blk.edge_imbalance(&g)
        );
        let total: usize = (0..8).map(|l| bal.len_of(l)).sum();
        assert_eq!(total, g.n());
    }

    #[test]
    fn degree_only_constructors_match_materialized() {
        // The streaming entry points must produce the exact same schemes
        // as their CSR-consuming twins.
        let g = generators::kron(8, 6, 19);
        let degrees: Vec<u32> = (0..g.n()).map(|u| g.degree(u as VertexId) as u32).collect();
        for p in [1u32, 3, 8] {
            assert_eq!(
                Partition1D::edge_balanced(&g, p),
                Partition1D::edge_balanced_from_degrees(&degrees, p)
            );
            let a = VertexCut2D::new(&g, p);
            let offsets = g.offsets();
            let targets = g.targets();
            let b = VertexCut2D::from_parts(
                g.n(),
                p,
                &degrees,
                (0..g.n()).flat_map(|u| {
                    targets[offsets[u]..offsets[u + 1]].iter().map(move |&v| (u as VertexId, v))
                }),
            );
            for v in 0..g.n() as VertexId {
                assert_eq!(a.owner(v), b.owner(v), "p={p} v={v}");
                assert_eq!(a.master_index(v), b.master_index(v), "p={p} v={v}");
            }
            for e in 0..g.m() {
                assert_eq!(a.edge_home(0, e), b.edge_home(0, e), "p={p} e={e}");
            }
            assert_eq!(a.replication_factor(), b.replication_factor());
        }
    }

    #[test]
    fn edge_balanced_degenerate_cuts_are_deterministic() {
        // p > n: surplus localities must get empty ranges, the cover must
        // stay exact, and every owner query must stay in range.
        let g = generators::path(3); // n=3, m=2
        let p = Partition1D::edge_balanced(&g, 8);
        assert_eq!(p.p(), 8);
        let total: usize = (0..8).map(|l| p.len_of(l)).sum();
        assert_eq!(total, 3);
        assert!((0..8).any(|l| p.len_of(l) == 0), "p > n must produce empty ranges");
        for v in 0..3u32 {
            let l = Partition1D::owner(&p, v);
            assert!(p.range_of(l).contains(&(v as usize)));
        }
        // Determinism: rebuilding gives identical cuts.
        assert_eq!(p, Partition1D::edge_balanced(&g, 8));
        check_scheme(&p, &g);
    }

    #[test]
    fn edge_balanced_prefix_plateau_emits_empty_ranges() {
        // One hub holds every edge, followed by a plateau of zero-degree
        // vertices: all interior cuts collapse onto the hub boundary and
        // the middle localities own empty (but valid) ranges.
        let mut el = crate::graph::EdgeList::new(12);
        for v in 1..12u32 {
            el.push(0, v);
        }
        let g = Csr::from_edge_list(&el); // deg(0)=11, deg(v>0)=0
        let p = Partition1D::edge_balanced(&g, 4);
        let total: usize = (0..4).map(|l| p.len_of(l)).sum();
        assert_eq!(total, 12);
        // Locality 0 gets the hub plus the whole zero-degree plateau;
        // the interior localities collapse to empty ranges.
        assert!(p.len_of(0) >= 1);
        assert!((0..4).any(|l| p.len_of(l) == 0), "plateau must yield an empty range");
        check_scheme(&p, &g);
        assert_eq!(p, Partition1D::edge_balanced(&g, 4));
    }

    #[test]
    fn single_locality_owns_all() {
        let p = Partition1D::block(42, 1);
        assert_eq!(p.range_of(0), 0..42);
        assert_eq!(p.owner(41), 0);
        assert_eq!(p.vertex_imbalance(), 1.0);
    }

    #[test]
    fn from_starts_validates() {
        let p = Partition1D::from_starts(vec![0, 2, 2, 5]);
        assert_eq!(p.p(), 3);
        assert_eq!(p.len_of(1), 0);
        assert_eq!(p.owner(2), 2);
    }

    #[test]
    fn hash_partition_is_consistent_and_spreads() {
        let g = generators::urand(8, 4, 7);
        for p in [1u32, 2, 4, 8] {
            let h = Hash1D::new(g.n(), p);
            check_scheme(&h, &g);
        }
        // A hash spread over 8 localities keeps every share within 2x of
        // the mean on 256 vertices (loose, deterministic bound).
        let h = Hash1D::new(256, 8);
        assert!(h.vertex_imbalance() < 2.0, "{}", h.vertex_imbalance());
    }

    #[test]
    fn vertex_cut_is_consistent_on_random_graphs() {
        for (scale, p) in [(6u32, 1u32), (6, 3), (7, 4), (7, 8)] {
            let g = generators::urand(scale, 4, 13 + p as u64);
            let vc = VertexCut2D::new(&g, p);
            check_scheme(&vc, &g);
            // Every edge is homed at a replica of both endpoints.
            let offsets = g.offsets();
            let targets = g.targets();
            for u in 0..g.n() {
                for e in offsets[u]..offsets[u + 1] {
                    let home = vc.edge_home(u as VertexId, e);
                    assert!(home < p);
                    let _ = targets[e];
                }
            }
        }
    }

    #[test]
    fn vertex_cut_balances_edges_on_kron() {
        // Tentpole acceptance: on the skewed kron10 graph at 8 localities
        // the greedy vertex cut achieves lower edge imbalance than the
        // block layout, at the price of replication_factor > 1.
        let g = generators::kron(10, 8, 11);
        let blk = Partition1D::block(g.n(), 8);
        let vc = VertexCut2D::new(&g, 8);
        let (bi, vi) = (
            PartitionScheme::edge_imbalance(&blk, &g),
            PartitionScheme::edge_imbalance(&vc, &g),
        );
        assert!(vi < bi, "vertex_cut {vi} must beat block {bi} on kron10@8");
        assert!(vi < 1.5, "greedy least-loaded should be near-balanced, got {vi}");
        assert!(vc.replication_factor() > 1.0);
        check_scheme(&vc, &g);
    }

    #[test]
    fn vertex_cut_single_locality_degenerates() {
        let g = generators::urand(6, 4, 3);
        let vc = VertexCut2D::new(&g, 1);
        assert_eq!(vc.replication_factor(), 1.0);
        assert_eq!(vc.owned_count(0), g.n());
        check_scheme(&vc, &g);
    }

    #[test]
    fn isolated_vertices_get_block_fallback_masters() {
        let el = crate::graph::EdgeList::new(8); // no edges at all
        let g = Csr::from_edge_list(&el);
        let vc = VertexCut2D::new(&g, 4);
        check_scheme(&vc, &g);
        assert_eq!(vc.replication_factor(), 1.0);
        // Block fallback spreads isolated vertices evenly.
        for l in 0..4 {
            assert_eq!(vc.owned_count(l), 2);
        }
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(PartitionKind::parse("block"), Some(PartitionKind::Block));
        assert_eq!(PartitionKind::parse("edge_balanced"), Some(PartitionKind::EdgeBalanced));
        assert_eq!(PartitionKind::parse("edge-balanced"), Some(PartitionKind::EdgeBalanced));
        assert_eq!(PartitionKind::parse("hash"), Some(PartitionKind::Hash));
        assert_eq!(PartitionKind::parse("vertex_cut"), Some(PartitionKind::VertexCut));
        assert_eq!(PartitionKind::parse("2d"), Some(PartitionKind::VertexCut));
        assert_eq!(PartitionKind::parse("diagonal"), None);
        let g = generators::urand(6, 4, 1);
        for kind in PartitionKind::all() {
            let s = kind.build(&g, 4);
            assert_eq!(s.name(), kind.name());
            assert_eq!(s.p(), 4);
            check_scheme(s.as_ref(), &g);
        }
    }
}
