//! 1-D vertex partitioning across localities.
//!
//! The paper distributes `hpx::partitioned_vector`-backed adjacency in
//! contiguous blocks; `vertex_locality_id` in Listing 1.2 is the owner
//! query. [`Partition1D`] generalizes the block layout to arbitrary
//! contiguous cuts so the edge-balanced strategy (an ablation in DESIGN.md)
//! shares the same interface.

use super::{Csr, VertexId};
use crate::amt::agas::BlockMap;
use crate::amt::sim::LocalityId;

/// A contiguous 1-D partition of `0..n` into `P` ranges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition1D {
    /// `starts[l]..starts[l+1]` is locality `l`'s range; `len == P + 1`.
    starts: Vec<usize>,
}

impl Partition1D {
    /// Equal-size block partition (HPX `container_layout` convention via
    /// [`BlockMap`]).
    pub fn block(n: usize, p: u32) -> Self {
        let map = BlockMap::new(n, p);
        let mut starts = Vec::with_capacity(p as usize + 1);
        starts.push(0);
        for l in 0..p {
            starts.push(map.range_of(l).end);
        }
        Partition1D { starts }
    }

    /// Edge-balanced contiguous partition: cuts chosen so each locality
    /// owns roughly `m / P` out-edges. Mitigates the load imbalance from
    /// skewed degree distributions (paper §2).
    pub fn edge_balanced(g: &Csr, p: u32) -> Self {
        let n = g.n();
        let m = g.m();
        let target = (m as f64 / p as f64).max(1.0);
        let offsets = g.offsets();
        let mut starts = Vec::with_capacity(p as usize + 1);
        starts.push(0);
        for l in 1..p as usize {
            let want = (l as f64 * target) as usize;
            // First vertex whose prefix edge count reaches `want`.
            let cut = offsets.partition_point(|&o| o < want).min(n);
            let prev = *starts.last().unwrap();
            starts.push(cut.max(prev)); // keep monotone
        }
        starts.push(n);
        Partition1D { starts }
    }

    /// From explicit cut points (must start at 0, end at n, be monotone).
    pub fn from_starts(starts: Vec<usize>) -> Self {
        assert!(starts.len() >= 2);
        assert_eq!(starts[0], 0);
        assert!(starts.windows(2).all(|w| w[0] <= w[1]));
        Partition1D { starts }
    }

    /// Locality count.
    pub fn p(&self) -> u32 {
        (self.starts.len() - 1) as u32
    }

    /// Total vertex count covered.
    pub fn n(&self) -> usize {
        *self.starts.last().unwrap()
    }

    /// The paper's `vertex_locality_id`: owner of vertex `v`.
    pub fn owner(&self, v: VertexId) -> LocalityId {
        let v = v as usize;
        debug_assert!(v < self.n());
        // partition_point returns the first start > v; owner is that - 1.
        (self.starts.partition_point(|&s| s <= v) - 1) as LocalityId
    }

    /// Vertex range owned by locality `l`.
    pub fn range_of(&self, l: LocalityId) -> std::ops::Range<usize> {
        let l = l as usize;
        self.starts[l]..self.starts[l + 1]
    }

    /// Number of vertices owned by `l`.
    pub fn len_of(&self, l: LocalityId) -> usize {
        let r = self.range_of(l);
        r.end - r.start
    }

    /// Every locality's owned range, in locality order — the destination
    /// layout handed to [`Aggregator::new`](crate::amt::Aggregator::new).
    pub fn ranges(&self) -> Vec<std::ops::Range<usize>> {
        (0..self.p()).map(|l| self.range_of(l)).collect()
    }

    /// Max / mean owned-vertex count (vertex balance factor).
    pub fn vertex_imbalance(&self) -> f64 {
        let p = self.p();
        let mean = self.n() as f64 / p as f64;
        if mean == 0.0 {
            return 1.0;
        }
        (0..p).map(|l| self.len_of(l) as f64).fold(0.0, f64::max) / mean
    }

    /// Max / mean owned-edge count under graph `g` (edge balance factor).
    pub fn edge_imbalance(&self, g: &Csr) -> f64 {
        let p = self.p();
        let mean = g.m() as f64 / p as f64;
        if mean == 0.0 {
            return 1.0;
        }
        let offsets = g.offsets();
        (0..p)
            .map(|l| {
                let r = self.range_of(l);
                (offsets[r.end] - offsets[r.start]) as f64
            })
            .fold(0.0, f64::max)
            / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn block_partition_owner_matches_range() {
        let p = Partition1D::block(10, 3); // 4,3,3
        assert_eq!(p.range_of(0), 0..4);
        assert_eq!(p.range_of(1), 4..7);
        assert_eq!(p.range_of(2), 7..10);
        for v in 0..10u32 {
            let l = p.owner(v);
            assert!(p.range_of(l).contains(&(v as usize)));
        }
    }

    #[test]
    fn partitions_cover_everything_once() {
        for (n, k) in [(10usize, 3u32), (1, 4), (100, 7), (64, 64)] {
            let p = Partition1D::block(n, k);
            let total: usize = (0..k).map(|l| p.len_of(l)).sum();
            assert_eq!(total, n);
        }
    }

    #[test]
    fn edge_balanced_beats_block_on_skewed_graphs() {
        let g = generators::kron(10, 8, 11);
        let blk = Partition1D::block(g.n(), 8);
        let bal = Partition1D::edge_balanced(&g, 8);
        assert!(
            bal.edge_imbalance(&g) <= blk.edge_imbalance(&g) + 1e-9,
            "balanced {} vs block {}",
            bal.edge_imbalance(&g),
            blk.edge_imbalance(&g)
        );
        let total: usize = (0..8).map(|l| bal.len_of(l)).sum();
        assert_eq!(total, g.n());
    }

    #[test]
    fn single_locality_owns_all() {
        let p = Partition1D::block(42, 1);
        assert_eq!(p.range_of(0), 0..42);
        assert_eq!(p.owner(41), 0);
        assert_eq!(p.vertex_imbalance(), 1.0);
    }

    #[test]
    fn from_starts_validates() {
        let p = Partition1D::from_starts(vec![0, 2, 2, 5]);
        assert_eq!(p.p(), 3);
        assert_eq!(p.len_of(1), 0);
        assert_eq!(p.owner(2), 2);
    }
}
