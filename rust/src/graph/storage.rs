//! Pluggable adjacency storage: plain arrays or delta-varint compressed
//! rows.
//!
//! The memory wall the ROADMAP's "billion-edge scale" item targets is
//! adjacency: plain CSR spends 4 bytes per edge on the target id (8 when
//! a shard keeps both the global and the dense-local view) plus 8 bytes
//! per row of `usize` offsets. Sorted rows compress well — consecutive
//! targets are close, so delta + LEB128 varint encoding stores most
//! entries in 1–2 bytes. [`AdjRows`] is the shared row container behind
//! both [`Csr`] wrappers and [`Shard`](super::Shard) adjacency:
//!
//! * **Plain** keeps the historical flat arrays (zero-copy row slices);
//! * **Compressed** stores each row as `[count][zigzag-delta varints...]`
//!   over a shared byte buffer with 4-byte per-row byte offsets.
//!
//! Rows decode through [`RowIter`] (allocation-free streaming decode) or
//! into a caller-owned scratch `Vec` (the decode-scratch contract: hot
//! loops own one reusable buffer each, in the spirit of the pooled
//! aggregator combiners). Zigzag encoding is used even for ascending
//! rows so the same codec serves the shard's dense-local row view, which
//! is *not* monotone (owned and ghost row indices interleave).
//!
//! [`AdjacencyStorage`] is the trait consumers iterate through without
//! knowing the encoding; [`Csr`], [`AdjRows`], and [`CompressedCsr`]
//! implement it.

use super::{Csr, VertexId};

/// Which adjacency encoding shards use — the `storage` config/CLI key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StorageKind {
    /// Flat offset/target arrays (the historical layout).
    #[default]
    Plain,
    /// Delta-encoded varint rows over a shared byte buffer.
    Compressed,
}

impl StorageKind {
    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Option<StorageKind> {
        match s {
            "plain" => Some(StorageKind::Plain),
            "compressed" | "varint" => Some(StorageKind::Compressed),
            _ => None,
        }
    }

    /// Config spelling of this kind.
    pub fn name(&self) -> &'static str {
        match self {
            StorageKind::Plain => "plain",
            StorageKind::Compressed => "compressed",
        }
    }
}

/// Append `v` as an LEB128 varint (7 bits per byte, high bit = continue).
#[inline]
pub fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8 & 0x7f) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Read one LEB128 varint from the front of `bytes`, advancing the slice.
#[inline]
pub fn take_varint(bytes: &mut &[u8]) -> u64 {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = bytes[0];
        *bytes = &bytes[1..];
        v |= u64::from(b & 0x7f) << shift;
        if b < 0x80 {
            return v;
        }
        shift += 7;
        debug_assert!(shift < 64, "varint longer than 64 bits");
    }
}

/// Zigzag-map a signed delta to an unsigned varint payload
/// (`0, -1, 1, -2, ...` → `0, 1, 2, 3, ...`).
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Streaming decoder over one adjacency row, for either encoding.
/// Allocation-free; yields the row's stored values in order.
#[derive(Debug, Clone)]
pub enum RowIter<'a> {
    /// Plain row: a slice walk.
    Slice(std::slice::Iter<'a, u32>),
    /// Compressed row: zigzag-delta varint decode.
    Delta {
        /// Remaining encoded bytes of this row.
        bytes: &'a [u8],
        /// Entries left to decode.
        remaining: usize,
        /// Running delta accumulator.
        prev: i64,
    },
}

impl<'a> Iterator for RowIter<'a> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        match self {
            RowIter::Slice(it) => it.next().copied(),
            RowIter::Delta { bytes, remaining, prev } => {
                if *remaining == 0 {
                    return None;
                }
                *remaining -= 1;
                *prev += unzigzag(take_varint(bytes));
                debug_assert!(*prev >= 0 && *prev <= u32::MAX as i64);
                Some(*prev as u32)
            }
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = match self {
            RowIter::Slice(it) => it.len(),
            RowIter::Delta { remaining, .. } => *remaining,
        };
        (n, Some(n))
    }
}

impl<'a> ExactSizeIterator for RowIter<'a> {}

/// Row-oriented adjacency consumed without knowledge of the encoding.
/// The scratch-taking [`AdjacencyStorage::row`] is zero-copy for plain
/// storage and decodes into the caller's buffer for compressed storage —
/// callers own one scratch per hot loop and reuse it across rows.
pub trait AdjacencyStorage {
    /// Number of rows.
    fn n_rows(&self) -> usize;

    /// Entry count of `row`.
    fn row_len(&self, row: usize) -> usize;

    /// Streaming decode of `row`.
    fn iter_row(&self, row: usize) -> RowIter<'_>;

    /// `row` as a slice: plain storage returns its backing slice
    /// (ignoring `scratch`), compressed storage decodes into `scratch`.
    fn row<'a>(&'a self, row: usize, scratch: &'a mut Vec<u32>) -> &'a [u32];

    /// Total entries across all rows.
    fn total_entries(&self) -> usize;

    /// Bytes of heap this structure holds (by element count, not
    /// capacity, so the number is deterministic).
    fn heap_bytes(&self) -> usize;
}

/// The shared row container: one of the two encodings of
/// [`StorageKind`]. Values are whatever the owner stores per entry —
/// dense local rows for shard out-adjacency, global vertex ids for
/// in-adjacency and whole-graph CSRs.
#[derive(Debug, Clone, PartialEq)]
pub enum AdjRows {
    /// Flat arrays. `vals` is the canonical per-entry value; `globals`
    /// optionally carries a parallel global-id view (the historical
    /// shard layout keeps both so global reads stay zero-copy) and is
    /// empty when the owner needs only one view.
    Plain {
        /// Row boundaries, `len == n_rows + 1`.
        offsets: Vec<usize>,
        /// Canonical entry values, row-major.
        vals: Vec<u32>,
        /// Optional parallel global-id view (empty when unused).
        globals: Vec<VertexId>,
    },
    /// Per-row `[count varint][zigzag-delta varints...]` streams over one
    /// byte buffer. An empty byte range is an empty row (no count byte).
    Compressed {
        /// Byte boundaries into `bytes`, `len == n_rows + 1`.
        byte_offsets: Vec<u32>,
        /// Encoded row streams.
        bytes: Vec<u8>,
        /// Entry boundaries, `len == n_rows + 1`; built only when the
        /// owner indexes a parallel per-entry array (weights), else
        /// empty.
        entry_offsets: Vec<u32>,
        /// Total entries across all rows.
        total: usize,
    },
}

impl AdjRows {
    /// An empty container of the given kind.
    pub fn empty(kind: StorageKind) -> AdjRows {
        AdjRowsBuilder::new(kind, false, false).finish()
    }

    /// Which encoding this is.
    pub fn kind(&self) -> StorageKind {
        match self {
            AdjRows::Plain { .. } => StorageKind::Plain,
            AdjRows::Compressed { .. } => StorageKind::Compressed,
        }
    }

    /// First entry index of `row` in a parallel per-entry array (weights).
    /// Compressed rows must have been built with entry tracking.
    #[inline]
    pub fn entry_start(&self, row: usize) -> usize {
        match self {
            AdjRows::Plain { offsets, .. } => offsets[row],
            AdjRows::Compressed { entry_offsets, .. } => {
                debug_assert!(!entry_offsets.is_empty(), "built without entry tracking");
                entry_offsets[row] as usize
            }
        }
    }

    /// The parallel global-id view of `row`, when the encoding keeps one
    /// (plain dual layout only).
    #[inline]
    pub fn globals_slice(&self, row: usize) -> Option<&[VertexId]> {
        match self {
            AdjRows::Plain { offsets, globals, .. } if !globals.is_empty() => {
                Some(&globals[offsets[row]..offsets[row + 1]])
            }
            _ => None,
        }
    }
}

impl AdjacencyStorage for AdjRows {
    fn n_rows(&self) -> usize {
        match self {
            AdjRows::Plain { offsets, .. } => offsets.len() - 1,
            AdjRows::Compressed { byte_offsets, .. } => byte_offsets.len() - 1,
        }
    }

    fn row_len(&self, row: usize) -> usize {
        match self {
            AdjRows::Plain { offsets, .. } => offsets[row + 1] - offsets[row],
            AdjRows::Compressed { byte_offsets, bytes, entry_offsets, .. } => {
                if !entry_offsets.is_empty() {
                    return (entry_offsets[row + 1] - entry_offsets[row]) as usize;
                }
                let mut b = &bytes[byte_offsets[row] as usize..byte_offsets[row + 1] as usize];
                if b.is_empty() {
                    0
                } else {
                    take_varint(&mut b) as usize
                }
            }
        }
    }

    fn iter_row(&self, row: usize) -> RowIter<'_> {
        match self {
            AdjRows::Plain { offsets, vals, .. } => {
                RowIter::Slice(vals[offsets[row]..offsets[row + 1]].iter())
            }
            AdjRows::Compressed { byte_offsets, bytes, .. } => {
                let mut b = &bytes[byte_offsets[row] as usize..byte_offsets[row + 1] as usize];
                let remaining = if b.is_empty() { 0 } else { take_varint(&mut b) as usize };
                RowIter::Delta { bytes: b, remaining, prev: 0 }
            }
        }
    }

    fn row<'a>(&'a self, row: usize, scratch: &'a mut Vec<u32>) -> &'a [u32] {
        match self {
            AdjRows::Plain { offsets, vals, .. } => &vals[offsets[row]..offsets[row + 1]],
            rows @ AdjRows::Compressed { .. } => {
                scratch.clear();
                scratch.extend(rows.iter_row(row));
                scratch
            }
        }
    }

    fn total_entries(&self) -> usize {
        match self {
            AdjRows::Plain { vals, .. } => vals.len(),
            AdjRows::Compressed { total, .. } => *total,
        }
    }

    fn heap_bytes(&self) -> usize {
        match self {
            AdjRows::Plain { offsets, vals, globals } => {
                offsets.len() * std::mem::size_of::<usize>() + (vals.len() + globals.len()) * 4
            }
            AdjRows::Compressed { byte_offsets, bytes, entry_offsets, .. } => {
                (byte_offsets.len() + entry_offsets.len()) * 4 + bytes.len()
            }
        }
    }
}

/// Incremental [`AdjRows`] builder: `push` entries, `end_row` after each
/// row (including empty ones), `finish` when all rows are in.
#[derive(Debug)]
pub struct AdjRowsBuilder {
    kind: StorageKind,
    /// Track per-row entry offsets (needed when a parallel weight array
    /// will be indexed against compressed rows).
    track_entries: bool,
    /// Keep the parallel global-id view (plain dual layout).
    dual: bool,
    offsets: Vec<usize>,
    vals: Vec<u32>,
    globals: Vec<VertexId>,
    byte_offsets: Vec<u32>,
    bytes: Vec<u8>,
    entry_offsets: Vec<u32>,
    pending: Vec<u32>,
    total: usize,
}

impl AdjRowsBuilder {
    /// New builder. `track_entries` records per-row entry offsets for
    /// compressed rows (weights); `dual` keeps the parallel global view
    /// for plain rows.
    pub fn new(kind: StorageKind, track_entries: bool, dual: bool) -> AdjRowsBuilder {
        AdjRowsBuilder {
            kind,
            track_entries,
            dual,
            offsets: vec![0],
            vals: Vec::new(),
            globals: Vec::new(),
            byte_offsets: vec![0],
            bytes: Vec::new(),
            entry_offsets: vec![0],
            pending: Vec::new(),
            total: 0,
        }
    }

    /// Append one entry to the current row. `global` feeds the plain
    /// dual view and is ignored otherwise (pass `val` again when the
    /// canonical value *is* the global id).
    #[inline]
    pub fn push(&mut self, val: u32, global: VertexId) {
        match self.kind {
            StorageKind::Plain => {
                self.vals.push(val);
                if self.dual {
                    self.globals.push(global);
                }
            }
            StorageKind::Compressed => self.pending.push(val),
        }
    }

    /// Close the current row.
    pub fn end_row(&mut self) {
        match self.kind {
            StorageKind::Plain => {
                self.offsets.push(self.vals.len());
                self.total = self.vals.len();
            }
            StorageKind::Compressed => {
                if !self.pending.is_empty() {
                    write_varint(&mut self.bytes, self.pending.len() as u64);
                    let mut prev = 0i64;
                    for &v in &self.pending {
                        write_varint(&mut self.bytes, zigzag(v as i64 - prev));
                        prev = v as i64;
                    }
                }
                assert!(self.bytes.len() <= u32::MAX as usize, "compressed rows exceed 4 GiB");
                self.byte_offsets.push(self.bytes.len() as u32);
                self.total += self.pending.len();
                if self.track_entries {
                    self.entry_offsets.push(self.total as u32);
                }
                self.pending.clear();
            }
        }
    }

    /// Finalize into an [`AdjRows`].
    pub fn finish(self) -> AdjRows {
        debug_assert!(self.pending.is_empty(), "finish() before end_row()");
        match self.kind {
            StorageKind::Plain => AdjRows::Plain {
                offsets: self.offsets,
                vals: self.vals,
                globals: self.globals,
            },
            StorageKind::Compressed => AdjRows::Compressed {
                byte_offsets: self.byte_offsets,
                bytes: self.bytes,
                entry_offsets: if self.track_entries { self.entry_offsets } else { Vec::new() },
                total: self.total,
            },
        }
    }
}

impl AdjacencyStorage for Csr {
    fn n_rows(&self) -> usize {
        self.n()
    }

    fn row_len(&self, row: usize) -> usize {
        self.degree(row as VertexId)
    }

    fn iter_row(&self, row: usize) -> RowIter<'_> {
        RowIter::Slice(self.neighbors(row as VertexId).iter())
    }

    fn row<'a>(&'a self, row: usize, _scratch: &'a mut Vec<u32>) -> &'a [u32] {
        self.neighbors(row as VertexId)
    }

    fn total_entries(&self) -> usize {
        self.m()
    }

    fn heap_bytes(&self) -> usize {
        self.offsets().len() * std::mem::size_of::<usize>()
            + self.targets().len() * 4
            + self.weights().map_or(0, |w| w.len() * 4)
    }
}

/// A whole-graph CSR with delta-varint compressed rows — the sequential
/// counterpart of a compressed [`Shard`](super::Shard); weights stay a
/// parallel f32 array.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedCsr {
    rows: AdjRows,
    weights: Option<Vec<f32>>,
}

impl CompressedCsr {
    /// Compress an existing [`Csr`] (rows are already sorted, so deltas
    /// are non-negative and small).
    pub fn from_csr(g: &Csr) -> CompressedCsr {
        let weighted = g.is_weighted();
        let mut b = AdjRowsBuilder::new(StorageKind::Compressed, weighted, false);
        for u in 0..g.n() {
            for &v in g.neighbors(u as VertexId) {
                b.push(v, v);
            }
            b.end_row();
        }
        CompressedCsr { rows: b.finish(), weights: g.weights().map(<[f32]>::to_vec) }
    }

    /// Vertex count.
    pub fn n(&self) -> usize {
        self.rows.n_rows()
    }

    /// Directed edge count.
    pub fn m(&self) -> usize {
        self.rows.total_entries()
    }

    /// True when edges carry weights.
    pub fn is_weighted(&self) -> bool {
        self.weights.is_some()
    }

    /// Out-degree of `u`.
    pub fn degree(&self, u: VertexId) -> usize {
        self.rows.row_len(u as usize)
    }

    /// Streaming decode of `u`'s sorted out-neighbors.
    pub fn neighbors_iter(&self, u: VertexId) -> RowIter<'_> {
        self.rows.iter_row(u as usize)
    }

    /// Out-neighbors of `u` decoded into `scratch`.
    pub fn neighbors_into<'a>(&'a self, u: VertexId, scratch: &'a mut Vec<u32>) -> &'a [u32] {
        self.rows.row(u as usize, scratch)
    }

    /// Out-neighbors of `u` with weights; unweighted graphs yield unit
    /// weights.
    pub fn neighbors_weighted(&self, u: VertexId) -> impl Iterator<Item = (VertexId, f32)> + '_ {
        let start = if self.weights.is_some() { self.rows.entry_start(u as usize) } else { 0 };
        let w = self.weights.as_deref();
        self.rows
            .iter_row(u as usize)
            .enumerate()
            .map(move |(k, t)| (t, w.map(|w| w[start + k]).unwrap_or(1.0)))
    }
}

impl AdjacencyStorage for CompressedCsr {
    fn n_rows(&self) -> usize {
        self.rows.n_rows()
    }

    fn row_len(&self, row: usize) -> usize {
        self.rows.row_len(row)
    }

    fn iter_row(&self, row: usize) -> RowIter<'_> {
        self.rows.iter_row(row)
    }

    fn row<'a>(&'a self, row: usize, scratch: &'a mut Vec<u32>) -> &'a [u32] {
        self.rows.row(row, scratch)
    }

    fn total_entries(&self) -> usize {
        self.rows.total_entries()
    }

    fn heap_bytes(&self) -> usize {
        self.rows.heap_bytes() + self.weights.as_ref().map_or(0, |w| w.len() * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, 16383, 16384, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            write_varint(&mut buf, v);
        }
        let mut s = buf.as_slice();
        for &v in &vals {
            assert_eq!(take_varint(&mut s), v);
        }
        assert!(s.is_empty());
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, 1, -1, 2, -2, 1000, -1000, i32::MAX as i64, -(i32::MAX as i64)] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        // Small magnitudes map to small payloads (the point of zigzag).
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }

    fn roundtrip_rows(kind: StorageKind, rows: &[Vec<u32>]) {
        let mut b = AdjRowsBuilder::new(kind, true, false);
        for row in rows {
            for &v in row {
                b.push(v, v);
            }
            b.end_row();
        }
        let a = b.finish();
        assert_eq!(a.n_rows(), rows.len());
        let total: usize = rows.iter().map(Vec::len).sum();
        assert_eq!(a.total_entries(), total);
        let mut scratch = Vec::new();
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(a.row_len(i), row.len());
            assert_eq!(a.iter_row(i).collect::<Vec<_>>(), *row);
            assert_eq!(a.row(i, &mut scratch), row.as_slice());
        }
    }

    #[test]
    fn adj_rows_roundtrip_both_kinds() {
        let rows = vec![
            vec![1, 2, 3],
            vec![],
            vec![7],
            vec![100, 5, 9000, 2], // non-monotone (dense-local view)
            vec![],
            (0..200).map(|i| i * 3).collect(),
        ];
        roundtrip_rows(StorageKind::Plain, &rows);
        roundtrip_rows(StorageKind::Compressed, &rows);
    }

    #[test]
    fn entry_offsets_index_parallel_arrays() {
        let rows = [vec![4u32, 8], vec![], vec![1, 2, 3]];
        let mut b = AdjRowsBuilder::new(StorageKind::Compressed, true, false);
        for row in &rows {
            for &v in row {
                b.push(v, v);
            }
            b.end_row();
        }
        let a = b.finish();
        assert_eq!(a.entry_start(0), 0);
        assert_eq!(a.entry_start(1), 2);
        assert_eq!(a.entry_start(2), 2);
        assert_eq!(a.total_entries(), 5);
    }

    #[test]
    fn plain_dual_view_keeps_globals() {
        let mut b = AdjRowsBuilder::new(StorageKind::Plain, false, true);
        b.push(3, 30);
        b.push(1, 10);
        b.end_row();
        b.end_row(); // empty row
        let a = b.finish();
        assert_eq!(a.globals_slice(0), Some(&[30u32, 10][..]));
        assert_eq!(a.globals_slice(1), Some(&[][..]));
        assert_eq!(a.iter_row(0).collect::<Vec<_>>(), vec![3, 1]);
    }

    #[test]
    fn compressed_csr_matches_plain() {
        let g = generators::kron(8, 6, 5);
        let c = CompressedCsr::from_csr(&g);
        assert_eq!(c.n(), g.n());
        assert_eq!(c.m(), g.m());
        let mut scratch = Vec::new();
        for u in 0..g.n() as VertexId {
            assert_eq!(c.degree(u), g.degree(u));
            assert_eq!(c.neighbors_into(u, &mut scratch), g.neighbors(u));
            assert_eq!(c.neighbors_iter(u).collect::<Vec<_>>(), g.neighbors(u));
        }
    }

    #[test]
    fn compressed_csr_carries_weights() {
        let g = generators::with_symmetric_random_weights(&generators::urand(7, 4, 3), 1.0, 9.0, 4);
        let c = CompressedCsr::from_csr(&g);
        assert!(c.is_weighted());
        for u in 0..g.n() as VertexId {
            let want: Vec<(VertexId, f32)> = g.neighbors_weighted(u).collect();
            let got: Vec<(VertexId, f32)> = c.neighbors_weighted(u).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn compressed_csr_is_smaller_on_sorted_rows() {
        let g = generators::kron(10, 8, 7);
        let c = CompressedCsr::from_csr(&g);
        let (pb, cb) = (g.heap_bytes(), c.heap_bytes());
        assert!(
            (cb as f64) < 0.6 * pb as f64,
            "compressed {cb} should be well under 60% of plain {pb}"
        );
    }

    #[test]
    fn storage_kind_parses() {
        assert_eq!(StorageKind::parse("plain"), Some(StorageKind::Plain));
        assert_eq!(StorageKind::parse("compressed"), Some(StorageKind::Compressed));
        assert_eq!(StorageKind::parse("varint"), Some(StorageKind::Compressed));
        assert_eq!(StorageKind::parse("zip"), None);
        assert_eq!(StorageKind::default(), StorageKind::Plain);
        assert_eq!(StorageKind::Compressed.name(), "compressed");
    }

    #[test]
    fn empty_container_is_empty() {
        for kind in [StorageKind::Plain, StorageKind::Compressed] {
            let a = AdjRows::empty(kind);
            assert_eq!(a.n_rows(), 0);
            assert_eq!(a.total_entries(), 0);
            assert_eq!(a.kind(), kind);
        }
    }
}
