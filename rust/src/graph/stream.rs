//! Streaming shard construction: build a [`DistGraph`] from an edge
//! stream without ever materializing the whole-graph [`EdgeList`] or
//! [`Csr`](super::Csr).
//!
//! The materialized path ([`DistGraph::build_with_storage`]) needs the
//! full CSR in one address space before it can route edges — that is the
//! memory wall the scale sweep (ablation A9) measures. This module
//! replays the same edge population straight from its *source* (the raw
//! generator sampling, or an edge-list file) and routes it through the
//! exact pipeline the materialized builder uses, so the resulting shards
//! are **deeply equal** (`Shard: PartialEq`) to the materialized ones:
//!
//! 1. **Scatter**: raw pairs land in `p` ingest buckets keyed by the
//!    block owner of the source vertex (contiguous ascending ranges, so
//!    bucket concatenation is global CSR order). Symmetric sources emit
//!    both directions and drop self loops — the streaming equivalent of
//!    [`EdgeList::symmetrize`].
//! 2. **Sort + dedup** per bucket (stable keep-first for file weights,
//!    matching [`EdgeList::dedup`]); per-vertex degrees and the global
//!    `m` accumulate here.
//! 3. **Scheme build** from degrees alone:
//!    [`Partition1D::edge_balanced_from_degrees`] and
//!    [`VertexCut2D::from_parts`] are the streaming twins of the
//!    CSR-consuming constructors (bit-identical schemes).
//! 4. **Routing**: one pass over the buckets in order, tracking the
//!    running global edge index so `edge_home(u, e)` sequences exactly
//!    as on the materialized CSR; each bucket is dropped as it drains.
//!    Synthetic weights are stamped here via the pair-keyed
//!    [`symmetric_weight`] draw (a pure function of the endpoints, so no
//!    sequential RNG state is needed —
//!    [`with_random_weights`](super::generators::with_random_weights) is
//!    sequence-dependent and deliberately *not* reproducible from a
//!    stream).
//! 5. **Assemble** per locality through the shared
//!    [`assemble_shard`](super::distributed) seam.
//!
//! [`MemStats::peak_builder_bytes`](crate::amt::metrics::MemStats) here
//! models distributed memory: the
//! largest *per-locality* transient (ingest bucket + routed out/in
//! buffers + the replicated degree array), not the leader-resident sum
//! the materialized builder reports — the number the acceptance
//! criterion compares.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use super::distributed::{assemble_shard, finish_mirrors, DistGraph};
use super::generators::{sample_rmat, sample_urand, symmetric_weight};
use super::partition::{Hash1D, Partition1D, PartitionKind, PartitionScheme, VertexCut2D};
use super::storage::StorageKind;
use super::VertexId;
use crate::amt::agas::BlockMap;
use crate::Result;

/// Where the edge stream comes from. Synthetic sources replay the raw
/// generator sampling (`sample_urand` / `sample_rmat`), so a streamed
/// build sees the exact edge population of the materialized generator
/// with the same parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum EdgeSource {
    /// GAP urand: symmetrized Erdős–Rényi (matches [`generators::urand`](super::generators::urand)).
    Urand {
        /// `n = 2^scale` vertices.
        scale: u32,
        /// Average directed degree before symmetrization.
        degree: usize,
        /// PRNG seed.
        seed: u64,
    },
    /// Directed Erdős–Rényi (matches [`generators::urand_directed`](super::generators::urand_directed)).
    UrandDirected {
        /// `n = 2^scale` vertices.
        scale: u32,
        /// Average degree.
        degree: usize,
        /// PRNG seed.
        seed: u64,
    },
    /// Graph500 RMAT/Kronecker, symmetrized (matches [`generators::rmat`](super::generators::rmat) /
    /// [`generators::kron`](super::generators::kron)).
    Rmat {
        /// `n = 2^scale` vertices.
        scale: u32,
        /// Average directed degree before symmetrization.
        degree: usize,
        /// Quadrant probability a.
        a: f64,
        /// Quadrant probability b.
        b: f64,
        /// Quadrant probability c.
        c: f64,
        /// PRNG seed.
        seed: u64,
    },
    /// Whitespace edge-list file (`u v [w]` lines, `#`/`%` comments),
    /// identical parsing to [`io::read_edge_list`](super::io::read_edge_list).
    /// Read twice (vertex-count scan, then scatter) so the edge set is
    /// never held whole; no dedup/symmetrization, matching the
    /// materialized file path.
    File(PathBuf),
}

impl EdgeSource {
    /// Graph500-parameterized kron source (the A9 sweep input).
    pub fn kron(scale: u32, degree: usize, seed: u64) -> EdgeSource {
        EdgeSource::Rmat { scale, degree, a: 0.57, b: 0.19, c: 0.19, seed }
    }

    /// Map a config `generator` name to its streaming source.
    pub fn from_generator(name: &str, scale: u32, degree: usize, seed: u64) -> Result<EdgeSource> {
        Ok(match name {
            "urand" => EdgeSource::Urand { scale, degree, seed },
            "urand-directed" => EdgeSource::UrandDirected { scale, degree, seed },
            "kron" => EdgeSource::kron(scale, degree, seed),
            other => anyhow::bail!("unknown generator `{other}`"),
        })
    }

    /// Both directions of every raw pair are ingested (and self loops
    /// dropped) — the streaming [`EdgeList::symmetrize`].
    fn symmetric(&self) -> bool {
        matches!(self, EdgeSource::Urand { .. } | EdgeSource::Rmat { .. })
    }

    /// Whether duplicates are removed (synthetic sources dedup like their
    /// generators; files keep duplicates like the materialized file path).
    fn dedups(&self) -> bool {
        !matches!(self, EdgeSource::File(_))
    }
}

/// Pair-keyed synthetic weights stamped during routing — the streaming
/// twin of
/// [`with_symmetric_random_weights`](super::generators::with_symmetric_random_weights).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WeightSpec {
    /// Inclusive lower bound.
    pub lo: f32,
    /// Exclusive upper bound.
    pub hi: f32,
    /// Draw seed (pair-keyed, order-independent).
    pub seed: u64,
}

#[derive(Default)]
struct Bucket {
    pairs: Vec<(VertexId, VertexId)>,
    /// Parallel file-carried weights (empty otherwise).
    weights: Vec<f32>,
}

impl Bucket {
    fn bytes(&self) -> usize {
        self.pairs.len() * std::mem::size_of::<(VertexId, VertexId)>() + self.weights.len() * 4
    }
}

/// Build a [`DistGraph`] straight from `src` — the whole-graph
/// `EdgeList`/`Csr` is never constructed. `weights` stamps pair-keyed
/// synthetic weights on generator sources (rejected for files, which
/// carry their own).
pub fn build_streamed(
    src: &EdgeSource,
    kind: PartitionKind,
    p: u32,
    storage: StorageKind,
    weights: Option<WeightSpec>,
) -> Result<DistGraph> {
    let started = Instant::now();
    anyhow::ensure!(p > 0, "need at least one locality");
    anyhow::ensure!(
        weights.is_none() || !matches!(src, EdgeSource::File(_)),
        "file sources carry their own weights; WeightSpec applies to generators"
    );

    // Stage 1: scatter raw pairs into per-locality ingest buckets keyed
    // by the block owner of the source vertex.
    let (n, mut buckets) = scatter(src, p)?;
    let file_weighted = buckets.iter().any(|b| !b.weights.is_empty());
    if file_weighted {
        // A partially weighted file pads with 1.0, like read_edge_list.
        for b in &mut buckets {
            b.weights.resize(b.pairs.len(), 1.0);
        }
    }

    // Stage 2: per-bucket sort (+ dedup keep-first), then degrees.
    for b in &mut buckets {
        if file_weighted {
            let mut zipped: Vec<((VertexId, VertexId), f32)> =
                b.pairs.iter().copied().zip(b.weights.iter().copied()).collect();
            zipped.sort_by_key(|&(e, _)| e); // stable: duplicate order kept
            if src.dedups() {
                zipped.dedup_by_key(|&mut (e, _)| e);
            }
            b.pairs = zipped.iter().map(|&(e, _)| e).collect();
            b.weights = zipped.iter().map(|&(_, w)| w).collect();
        } else {
            b.pairs.sort_unstable();
            if src.dedups() {
                b.pairs.dedup();
            }
        }
    }
    let mut degrees = vec![0u32; n];
    for b in &buckets {
        for &(u, _) in &b.pairs {
            degrees[u as usize] += 1;
        }
    }
    let m: usize = degrees.iter().map(|&d| d as usize).sum();

    // Stage 3: the partition scheme, from degrees and (for the vertex
    // cut) one read-only pass over the buckets in global CSR order.
    let scheme: Arc<dyn PartitionScheme> = match kind {
        PartitionKind::Block => Arc::new(Partition1D::block(n, p)),
        PartitionKind::EdgeBalanced => Arc::new(Partition1D::edge_balanced_from_degrees(&degrees, p)),
        PartitionKind::Hash => Arc::new(Hash1D::new(n, p)),
        PartitionKind::VertexCut => Arc::new(VertexCut2D::from_parts(
            n,
            p,
            &degrees,
            buckets.iter().flat_map(|b| b.pairs.iter().copied()),
        )),
    };

    // Stage 4: route every edge, draining buckets as they are consumed.
    // The running global index makes `edge_home(u, e)` sequence exactly
    // as on the materialized CSR (buckets concatenate in CSR order).
    let weighted = weights.is_some() || file_weighted;
    let mut homed: Vec<Vec<(VertexId, VertexId, f32)>> = vec![Vec::new(); p as usize];
    let mut in_bufs: Vec<Vec<(VertexId, VertexId)>> = vec![Vec::new(); p as usize];
    let bucket_bytes: Vec<usize> = buckets.iter().map(Bucket::bytes).collect();
    let mut e = 0usize;
    for b in &mut buckets {
        let b = std::mem::take(b);
        for (i, &(u, v)) in b.pairs.iter().enumerate() {
            let w = if let Some(ws) = weights {
                symmetric_weight(ws.seed, ws.lo, ws.hi, u, v)
            } else if file_weighted {
                b.weights[i]
            } else {
                1.0
            };
            homed[scheme.edge_home(u, e) as usize].push((u, v, w));
            in_bufs[scheme.owner(v) as usize].push((v, u));
            e += 1;
        }
    }
    debug_assert_eq!(e, m);
    for buf in &mut in_bufs {
        buf.sort_unstable();
    }
    // Peak per-locality transient: ingest bucket + routed buffers + the
    // replicated degree array (distributed-memory model; the
    // materialized builder reports the leader-resident sum instead).
    let peak = (0..p as usize)
        .map(|l| {
            bucket_bytes[l]
                + homed[l].len() * std::mem::size_of::<(VertexId, VertexId, f32)>()
                + in_bufs[l].len() * std::mem::size_of::<(VertexId, VertexId)>()
        })
        .max()
        .unwrap_or(0)
        + degrees.len() * 4;

    // Stage 5: per-locality assembly through the shared seam.
    let mut shards = Vec::with_capacity(p as usize);
    for l in 0..p {
        let owned_ids = scheme.owned_vertices(l);
        let out_degree = owned_ids.iter().map(|&v| degrees[v as usize]).collect();
        shards.push(assemble_shard(
            l,
            owned_ids,
            out_degree,
            scheme.as_ref(),
            &homed[l as usize],
            &in_bufs[l as usize],
            weighted,
            storage,
        ));
    }
    finish_mirrors(&mut shards, n);
    Ok(DistGraph::from_parts(scheme, shards, n, m, storage, peak, started))
}

/// Stage 1: raw pairs into block-keyed buckets. Returns `(n, buckets)`.
fn scatter(src: &EdgeSource, p: u32) -> Result<(usize, Vec<Bucket>)> {
    let mut buckets: Vec<Bucket> = (0..p).map(|_| Bucket::default()).collect();
    match *src {
        EdgeSource::Urand { scale, degree, seed } | EdgeSource::UrandDirected { scale, degree, seed } => {
            let n = 1usize << scale;
            let map = BlockMap::new(n, p);
            let symmetric = src.symmetric();
            sample_urand(scale, degree, seed, |u, v| {
                if u != v {
                    buckets[map.resolve(u as usize).locality as usize].pairs.push((u, v));
                    if symmetric {
                        buckets[map.resolve(v as usize).locality as usize].pairs.push((v, u));
                    }
                }
            });
            Ok((n, buckets))
        }
        EdgeSource::Rmat { scale, degree, a, b, c, seed } => {
            let n = 1usize << scale;
            let map = BlockMap::new(n, p);
            sample_rmat(scale, degree, a, b, c, seed, |u, v| {
                // sample_rmat already drops self loops.
                buckets[map.resolve(u as usize).locality as usize].pairs.push((u, v));
                buckets[map.resolve(v as usize).locality as usize].pairs.push((v, u));
            });
            Ok((n, buckets))
        }
        EdgeSource::File(ref path) => {
            // Pass 1: vertex count only.
            let mut n = 0usize;
            for_each_file_edge(path, |u, v, _| {
                n = n.max(u as usize + 1).max(v as usize + 1);
                Ok(())
            })?;
            let map = BlockMap::new(n, p);
            for_each_file_edge(path, |u, v, w| {
                let b = &mut buckets[map.resolve(u as usize).locality as usize];
                b.pairs.push((u, v));
                if let Some(w) = w {
                    b.weights.resize(b.pairs.len() - 1, 1.0);
                    b.weights.push(w);
                }
                Ok(())
            })?;
            Ok((n, buckets))
        }
    }
}

/// Line-by-line edge-list parse shared by both file passes — the same
/// grammar as [`io::read_edge_list`](super::io::read_edge_list), without
/// ever holding the edge set.
fn for_each_file_edge(
    path: &PathBuf,
    mut f: impl FnMut(VertexId, VertexId, Option<f32>) -> Result<()>,
) -> Result<()> {
    use std::io::{BufRead, BufReader};
    let file = std::fs::File::open(path)?;
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let s = line.trim();
        if s.is_empty() || s.starts_with('#') || s.starts_with('%') {
            continue;
        }
        let mut it = s.split_whitespace();
        let u: usize = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing source", lineno + 1))?
            .parse()?;
        let v: usize = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing target", lineno + 1))?
            .parse()?;
        let w: Option<f32> = it.next().map(|t| t.parse()).transpose()?;
        f(u as VertexId, v as VertexId, w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr, DistGraph};

    fn materialized(g: &Csr, kind: PartitionKind, p: u32, storage: StorageKind) -> DistGraph {
        DistGraph::build_with_storage(g, kind.build(g, p), storage)
    }

    fn assert_dist_eq(a: &DistGraph, b: &DistGraph, ctx: &str) {
        assert_eq!(a.n(), b.n(), "{ctx}: n");
        assert_eq!(a.m(), b.m(), "{ctx}: m");
        assert_eq!(a.shards.len(), b.shards.len(), "{ctx}: p");
        for (sa, sb) in a.shards.iter().zip(&b.shards) {
            assert_eq!(sa, sb, "{ctx}: shard {} diverges", sa.locality);
        }
    }

    #[test]
    fn streamed_kron_equals_materialized_everywhere() {
        let g = generators::kron(7, 6, 9);
        let src = EdgeSource::kron(7, 6, 9);
        for kind in PartitionKind::all() {
            for storage in [StorageKind::Plain, StorageKind::Compressed] {
                let want = materialized(&g, kind, 4, storage);
                let got = build_streamed(&src, kind, 4, storage, None).unwrap();
                assert_dist_eq(&got, &want, &format!("{kind:?}/{storage:?}"));
            }
        }
    }

    #[test]
    fn streamed_urand_and_directed_equal_materialized() {
        let gu = generators::urand(6, 4, 5);
        let got = build_streamed(
            &EdgeSource::Urand { scale: 6, degree: 4, seed: 5 },
            PartitionKind::Block,
            3,
            StorageKind::Plain,
            None,
        )
        .unwrap();
        assert_dist_eq(&got, &materialized(&gu, PartitionKind::Block, 3, StorageKind::Plain), "urand");

        let gd = generators::urand_directed(6, 4, 5);
        let got = build_streamed(
            &EdgeSource::UrandDirected { scale: 6, degree: 4, seed: 5 },
            PartitionKind::EdgeBalanced,
            3,
            StorageKind::Compressed,
            None,
        )
        .unwrap();
        assert_dist_eq(
            &got,
            &materialized(&gd, PartitionKind::EdgeBalanced, 3, StorageKind::Compressed),
            "urand-directed",
        );
    }

    #[test]
    fn streamed_symmetric_weights_equal_materialized() {
        let g = generators::with_symmetric_random_weights(&generators::urand(6, 4, 7), 1.0, 10.0, 11);
        let src = EdgeSource::Urand { scale: 6, degree: 4, seed: 7 };
        let spec = WeightSpec { lo: 1.0, hi: 10.0, seed: 11 };
        for kind in PartitionKind::all() {
            let want = materialized(&g, kind, 4, StorageKind::Compressed);
            let got = build_streamed(&src, kind, 4, StorageKind::Compressed, Some(spec)).unwrap();
            assert_dist_eq(&got, &want, &format!("weighted/{kind:?}"));
            assert!(got.is_weighted());
        }
    }

    #[test]
    fn streamed_peak_is_below_materialized_peak() {
        let g = generators::kron(9, 8, 3);
        let want = materialized(&g, PartitionKind::Block, 8, StorageKind::Compressed);
        let got = build_streamed(
            &EdgeSource::kron(9, 8, 3),
            PartitionKind::Block,
            8,
            StorageKind::Compressed,
            None,
        )
        .unwrap();
        assert_dist_eq(&got, &want, "peak");
        let (sp, mp) =
            (got.mem_stats().peak_builder_bytes, want.mem_stats().peak_builder_bytes);
        assert!(sp > 0 && mp > 0);
        assert!(
            sp < mp,
            "streamed per-locality peak {sp} should undercut materialized leader peak {mp}"
        );
    }

    #[test]
    fn file_source_roundtrips() {
        let g = generators::with_symmetric_random_weights(&generators::urand(5, 4, 13), 1.0, 5.0, 3);
        let dir = std::env::temp_dir();
        let path = dir.join(format!("nwgraph_stream_test_{}.el", std::process::id()));
        {
            let f = std::fs::File::create(&path).unwrap();
            crate::graph::io::write_edge_list(&g, f).unwrap();
        }
        let got = build_streamed(
            &EdgeSource::File(path.clone()),
            PartitionKind::Block,
            3,
            StorageKind::Plain,
            None,
        )
        .unwrap();
        std::fs::remove_file(&path).ok();
        // The materialized file path: read_edge_list -> Csr (no dedup).
        let want = materialized(&g, PartitionKind::Block, 3, StorageKind::Plain);
        assert_dist_eq(&got, &want, "file");
        assert!(got.is_weighted());
    }

    #[test]
    fn weight_spec_rejected_for_files() {
        let err = build_streamed(
            &EdgeSource::File(PathBuf::from("/nonexistent")),
            PartitionKind::Block,
            1,
            StorageKind::Plain,
            Some(WeightSpec { lo: 1.0, hi: 2.0, seed: 0 }),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("WeightSpec"), "{err}");
    }

    #[test]
    fn generator_names_map() {
        assert_eq!(
            EdgeSource::from_generator("kron", 5, 4, 1).unwrap(),
            EdgeSource::kron(5, 4, 1)
        );
        assert!(EdgeSource::from_generator("mesh", 5, 4, 1).is_err());
    }
}
