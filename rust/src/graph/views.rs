//! NWGraph-style traversal views: `bfs_range`-like iterators that express
//! algorithms as high-level ranges instead of visitor objects (paper §3.1).

use std::collections::VecDeque;

use super::{Csr, VertexId};

/// Iterator over `(vertex, level)` in BFS order from a source — the
/// NWGraph `bfs_range` view.
pub struct BfsRange<'g> {
    g: &'g Csr,
    queue: VecDeque<(VertexId, usize)>,
    visited: Vec<bool>,
}

impl<'g> BfsRange<'g> {
    /// BFS view rooted at `source`.
    pub fn new(g: &'g Csr, source: VertexId) -> Self {
        let mut visited = vec![false; g.n()];
        let mut queue = VecDeque::new();
        if (source as usize) < g.n() {
            visited[source as usize] = true;
            queue.push_back((source, 0));
        }
        BfsRange { g, queue, visited }
    }
}

impl<'g> Iterator for BfsRange<'g> {
    type Item = (VertexId, usize);

    fn next(&mut self) -> Option<(VertexId, usize)> {
        let (u, lvl) = self.queue.pop_front()?;
        for &v in self.g.neighbors(u) {
            if !self.visited[v as usize] {
                self.visited[v as usize] = true;
                self.queue.push_back((v, lvl + 1));
            }
        }
        Some((u, lvl))
    }
}

/// Iterator over vertices in DFS (preorder) from a source.
pub struct DfsRange<'g> {
    g: &'g Csr,
    stack: Vec<VertexId>,
    visited: Vec<bool>,
}

impl<'g> DfsRange<'g> {
    /// DFS view rooted at `source`.
    pub fn new(g: &'g Csr, source: VertexId) -> Self {
        let mut visited = vec![false; g.n()];
        let mut stack = Vec::new();
        if (source as usize) < g.n() {
            visited[source as usize] = true;
            stack.push(source);
        }
        DfsRange { g, stack, visited }
    }
}

impl<'g> Iterator for DfsRange<'g> {
    type Item = VertexId;

    fn next(&mut self) -> Option<VertexId> {
        let u = self.stack.pop()?;
        // Reverse so lower-numbered neighbors come out first.
        for &v in self.g.neighbors(u).iter().rev() {
            if !self.visited[v as usize] {
                self.visited[v as usize] = true;
                self.stack.push(v);
            }
        }
        Some(u)
    }
}

/// All `(u, v)` edges as a flat iterator (the NWGraph `edge_range` view).
pub fn edge_range(g: &Csr) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
    (0..g.n() as VertexId).flat_map(move |u| g.neighbors(u).iter().map(move |&v| (u, v)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn bfs_range_levels_on_path() {
        let g = generators::path(5);
        let order: Vec<_> = BfsRange::new(&g, 0).collect();
        assert_eq!(order, vec![(0, 0), (1, 1), (2, 2), (3, 3), (4, 4)]);
    }

    #[test]
    fn bfs_range_visits_component_once() {
        let g = generators::urand(7, 4, 4);
        let mut seen = vec![false; g.n()];
        for (v, _) in BfsRange::new(&g, 0) {
            assert!(!seen[v as usize], "vertex {v} visited twice");
            seen[v as usize] = true;
        }
    }

    #[test]
    fn bfs_levels_are_monotone() {
        let g = generators::kron(8, 4, 5);
        let mut last = 0;
        for (_, lvl) in BfsRange::new(&g, 0) {
            assert!(lvl >= last);
            last = lvl;
        }
    }

    #[test]
    fn dfs_preorder_on_tree() {
        let g = generators::binary_tree(7);
        let order: Vec<_> = DfsRange::new(&g, 0).collect();
        assert_eq!(order.len(), 7);
        assert_eq!(order[0], 0);
        // first child of root explored fully before second
        let pos = |v: VertexId| order.iter().position(|&x| x == v).unwrap();
        assert!(pos(1) < pos(2));
        assert!(pos(3) < pos(2));
    }

    #[test]
    fn edge_range_counts_m() {
        let g = generators::urand(6, 4, 6);
        assert_eq!(edge_range(&g).count(), g.m());
    }
}
