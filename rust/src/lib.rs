//! # nwgraph-hpx — distributed graph algorithms on an asynchronous many-task runtime
//!
//! A reproduction of *"An Initial Evaluation of Distributed Graph Algorithms
//! using NWGraph and HPX"* (Mohammadiporshokooh, Syskakis, Kaiser — CS.DC 2026)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **[`amt`]** — an HPX-equivalent asynchronous many-task substrate: a
//!   discrete-event simulated multi-locality runtime (latency/bandwidth
//!   interconnect model, barriers), the [`amt::aggregate`] message
//!   coalescing layer (typed per-destination combiners with pluggable
//!   flush policies — unbatched / by-items / by-bytes / cost-model
//!   adaptive / drain-at-quiescence — and fold hooks for idempotent
//!   reductions) that every asynchronous algorithm routes remote actions
//!   through, plus real threaded work-stealing executors with static /
//!   dynamic / adaptive chunking for intra-locality parallel loops, an
//!   AGAS-style address resolver and an `hpx::partitioned_vector`
//!   equivalent.
//! * **[`graph`]** — an NWGraph-equivalent library: CSR adjacency, edge
//!   lists, GAP-style generators (`urand`, RMAT/Kronecker, structured),
//!   pluggable partition schemes (1-D block / edge-balanced / hash and a
//!   2-D greedy vertex cut) and distributed shards with ghost/mirror
//!   tables for master-index routing (CSR + masked-ELL).
//! * **[`engine`]** — the `VertexProgram` abstraction plus the three
//!   generic execution loops (asynchronous label-correcting, BSP
//!   supersteps, ordered delta buckets), owning all mirror routing,
//!   ghost-slot aggregation, termination, and report stamping. See
//!   `ARCHITECTURE.md` for the contract and the support matrix.
//! * **[`algorithms`]** — the paper's two algorithms plus the future-work
//!   extensions (§6), each a ~100-line `VertexProgram` (BFS, SSSP,
//!   PageRank, CC) dispatched onto the engines, with
//!   direction-optimizing BFS, kernel PageRank, and triangle counting as
//!   explicitly specialized engines. Async flavors aggregate via
//!   [`amt::FlushPolicy`] (the naive per-edge path survives only as
//!   `FlushPolicy::Unbatched`); BSP flavors drain their combiners once
//!   per superstep.
//! * **[`runtime`]** — PJRT wrapper loading the AOT-lowered Pallas/JAX
//!   compute kernels (`artifacts/*.hlo.txt`) for the kernel-offloaded
//!   PageRank / BFS local phases. Python never runs on this path.
//! * **[`coordinator`]** — experiment driver regenerating the paper's
//!   Figure 1 / Figure 2 sweeps and the ablations from DESIGN.md.
//!
//! See `DESIGN.md` for the full inventory and the substitutions made for
//! hardware we do not have (the paper's 32-node Ice Lake cluster becomes a
//! modeled interconnect; distributed BGL becomes a faithful BSP baseline on
//! the same substrate).

pub mod algorithms;
pub mod amt;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod graph;
pub mod runtime;
pub mod serve;
pub mod testing;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
