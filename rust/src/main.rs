//! `nwgraph-hpx` CLI — the leader entrypoint.
//!
//! See [`nwgraph_hpx::cli::USAGE`] (`nwgraph-hpx help`) for the command
//! grammar. All heavy lifting lives in [`nwgraph_hpx::coordinator`]; this
//! binary only parses arguments and formats output.

use std::path::PathBuf;

use nwgraph_hpx::cli::{Args, USAGE};
use nwgraph_hpx::config::Config;
use nwgraph_hpx::coordinator::{self, experiment, report::fmt_us, Engine};
use nwgraph_hpx::graph::degree;
use nwgraph_hpx::Result;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Print the `fault:` summary line when the run had anything to say —
/// a fault plan or reliability was configured, or a counter is nonzero.
/// Fault-free `reliability=none` runs stay silent (and all-zero by
/// construction, which the line makes observable when forced on).
fn print_fault(cfg: &Config, r: &nwgraph_hpx::amt::SimReport) {
    use nwgraph_hpx::amt::Reliability;
    if cfg.fault.is_none() && cfg.reliability == Reliability::None && r.fault.is_quiet() {
        return;
    }
    let f = &r.fault;
    println!(
        "  fault[{}]: drops={} dups={} delays={} retransmits={} dedup={} give-ups={} \
         crashes={} restores={} ckpts={} recovery-wall={}",
        if cfg.reliability.is_acked() { "acked" } else { "none" },
        f.injected_drops,
        f.injected_dups,
        f.injected_delays,
        f.retransmits,
        f.dedup_hits,
        f.give_ups,
        f.crashes,
        f.restores,
        f.checkpoints,
        fmt_us(f.recovery_wall_us),
    );
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    if args.command.is_empty() || args.command == "help" || args.switch("help") {
        println!("{USAGE}");
        return Ok(());
    }
    let cfg_path = args.flag("config").map(PathBuf::from);
    let mut cfg = Config::load(cfg_path.as_deref(), &args.overrides)?;
    if args.switch("aggregate") {
        cfg.aggregate = true;
    }
    if let Some(rt) = args.flag("runtime") {
        cfg.runtime = nwgraph_hpx::amt::RuntimeKind::parse(rt)
            .map_err(|e| anyhow::anyhow!("bad --runtime: {e}"))?;
    }
    let validate = args.switch("validate");

    match args.command.as_str() {
        "bfs" => {
            let engine = Engine::parse(args.flag("engine").unwrap_or("async"))?;
            let p = args.flag_or("p", *cfg.localities.last().unwrap_or(&4))?;
            let res = coordinator::run_bfs(&cfg, p, engine, validate)?;
            let reached = res.parents.iter().filter(|&&x| x >= 0).count();
            println!(
                "bfs[{engine:?}] {} p={p}: reached {}/{} vertices in {} (wall {}) \
                 (msgs={} envs={} barriers={})",
                cfg.graph_name(),
                reached,
                res.parents.len(),
                fmt_us(res.report.makespan_us),
                fmt_us(res.report.wall_us),
                res.report.net.messages,
                res.report.net.envelopes,
                res.report.barriers,
            );
            let pt = res.report.partition;
            println!(
                "  partition[{}]: v-imb={:.2} e-imb={:.2} repl={:.2}",
                cfg.partition.name(),
                pt.vertex_imbalance,
                pt.edge_imbalance,
                pt.replication_factor,
            );
            let mem = &res.report.mem;
            println!(
                "  mem[{}]: {:.2} B/edge shard-max={:.2}MB peak-build={:.2}MB build={:.1}ms",
                mem.storage,
                mem.bytes_per_edge,
                mem.max_shard_bytes as f64 / 1e6,
                mem.peak_builder_bytes as f64 / 1e6,
                mem.build_ms,
            );
            print_fault(&cfg, &res.report);
            if validate {
                println!("validation: OK");
            }
        }
        "pagerank" => {
            let engine = Engine::parse(args.flag("engine").unwrap_or("async"))?;
            let p = args.flag_or("p", *cfg.localities.last().unwrap_or(&4))?;
            let res = coordinator::run_pagerank(&cfg, p, engine, validate)?;
            println!(
                "pagerank[{engine:?}] {} p={p}: {} iters in {} (wall {}) \
                 (final delta={:.3e}, msgs={}, envs={}, barriers={})",
                cfg.graph_name(),
                cfg.iterations,
                fmt_us(res.report.makespan_us),
                fmt_us(res.report.wall_us),
                res.deltas.last().cloned().unwrap_or(0.0),
                res.report.net.messages,
                res.report.net.envelopes,
                res.report.barriers,
            );
            println!(
                "  mean busy={} imbalance={:.2} utilization={:.2} wire={}",
                fmt_us(res.report.mean_busy_us()),
                res.report.load_imbalance(),
                res.report.utilization(),
                fmt_us(res.report.net.wire_us),
            );
            println!(
                "  agg: pool reuse={:.2} obs-lat master={:.1}us mirror={:.1}us (acks={})",
                res.report.agg.pool_reuse_ratio(),
                res.report.agg_master.mean_obs_latency_us(),
                res.report.agg_mirror.mean_obs_latency_us(),
                res.report.agg.acks,
            );
            let pt = res.report.partition;
            println!(
                "  partition[{}]: v-imb={:.2} e-imb={:.2} repl={:.2}",
                cfg.partition.name(),
                pt.vertex_imbalance,
                pt.edge_imbalance,
                pt.replication_factor,
            );
            let mem = &res.report.mem;
            println!(
                "  mem[{}]: {:.2} B/edge shard-max={:.2}MB peak-build={:.2}MB build={:.1}ms",
                mem.storage,
                mem.bytes_per_edge,
                mem.max_shard_bytes as f64 / 1e6,
                mem.peak_builder_bytes as f64 / 1e6,
                mem.build_ms,
            );
            print_fault(&cfg, &res.report);
            if validate {
                println!("validation: OK");
            }
        }
        "sssp" => {
            let engine = Engine::parse(args.flag("engine").unwrap_or("delta"))?;
            let p = args.flag_or("p", *cfg.localities.last().unwrap_or(&4))?;
            let res = coordinator::run_sssp(&cfg, p, engine, validate)?;
            let reached = res.dist.iter().filter(|d| d.is_finite()).count();
            println!(
                "sssp[{engine:?}] {} p={p}: reached {}/{} vertices in {} (wall {}) \
                 (msgs={} envs={} barriers={})",
                cfg.graph_name(),
                reached,
                res.dist.len(),
                fmt_us(res.report.makespan_us),
                fmt_us(res.report.wall_us),
                res.report.net.messages,
                res.report.net.envelopes,
                res.report.barriers,
            );
            println!(
                "  relaxations={} useful={} efficiency={:.2} agg envelopes={} fold factor={:.1}",
                res.report.work.relaxations,
                res.report.work.useful_relaxations,
                res.report.work.efficiency(),
                res.report.agg.envelopes,
                res.report.agg.fold_factor(),
            );
            println!(
                "  agg: pool reuse={:.2} obs-lat master={:.1}us mirror={:.1}us (acks={})",
                res.report.agg.pool_reuse_ratio(),
                res.report.agg_master.mean_obs_latency_us(),
                res.report.agg_mirror.mean_obs_latency_us(),
                res.report.agg.acks,
            );
            let pt = res.report.partition;
            println!(
                "  partition[{}]: v-imb={:.2} e-imb={:.2} repl={:.2}",
                cfg.partition.name(),
                pt.vertex_imbalance,
                pt.edge_imbalance,
                pt.replication_factor,
            );
            let mem = &res.report.mem;
            println!(
                "  mem[{}]: {:.2} B/edge shard-max={:.2}MB peak-build={:.2}MB build={:.1}ms",
                mem.storage,
                mem.bytes_per_edge,
                mem.max_shard_bytes as f64 / 1e6,
                mem.peak_builder_bytes as f64 / 1e6,
                mem.build_ms,
            );
            print_fault(&cfg, &res.report);
            if validate {
                println!("validation: OK");
            }
        }
        "cc" => {
            let engine = Engine::parse(args.flag("engine").unwrap_or("bsp"))?;
            let p = args.flag_or("p", *cfg.localities.last().unwrap_or(&4))?;
            let res = coordinator::run_cc(&cfg, p, engine, validate)?;
            let comps = nwgraph_hpx::algorithms::cc::component_count(&res.labels);
            println!(
                "cc[{engine:?}] {} p={p}: {} components over {} vertices in {} (wall {}) \
                 (msgs={} envs={} barriers={})",
                cfg.graph_name(),
                comps,
                res.labels.len(),
                fmt_us(res.report.makespan_us),
                fmt_us(res.report.wall_us),
                res.report.net.messages,
                res.report.net.envelopes,
                res.report.barriers,
            );
            let pt = res.report.partition;
            println!(
                "  partition[{}]: v-imb={:.2} e-imb={:.2} repl={:.2}",
                cfg.partition.name(),
                pt.vertex_imbalance,
                pt.edge_imbalance,
                pt.replication_factor,
            );
            let mem = &res.report.mem;
            println!(
                "  mem[{}]: {:.2} B/edge shard-max={:.2}MB peak-build={:.2}MB build={:.1}ms",
                mem.storage,
                mem.bytes_per_edge,
                mem.max_shard_bytes as f64 / 1e6,
                mem.peak_builder_bytes as f64 / 1e6,
                mem.build_ms,
            );
            print_fault(&cfg, &res.report);
            if validate {
                println!("validation: OK");
            }
        }
        "serve" => {
            let engine = Engine::parse(args.flag("engine").unwrap_or("serve"))?;
            let p = args.flag_or("p", *cfg.localities.last().unwrap_or(&4))?;
            let res = coordinator::run_serve(&cfg, p, engine, validate)?;
            let q = res.report.query;
            println!(
                "serve {} p={p}: {} queries in {} wall, {:.0} q/s \
                 (landmarks={} cache={} batch={})",
                cfg.graph_name(),
                q.queries,
                fmt_us(res.report.wall_us),
                q.qps,
                if cfg.serve_oracle { cfg.serve_landmarks } else { 0 },
                cfg.serve_cache,
                cfg.serve_batch,
            );
            println!(
                "  oracle hits={} cache hits={} (hit rate {:.2}) waves={}",
                q.oracle_hits,
                q.cache_hits,
                q.hit_rate(),
                q.waves,
            );
            println!(
                "  latency: p50={:.1}us p99={:.1}us (msgs={} envs={} barriers={})",
                q.p50_us,
                q.p99_us,
                res.report.net.messages,
                res.report.net.envelopes,
                res.report.barriers,
            );
            let pt = res.report.partition;
            println!(
                "  partition[{}]: v-imb={:.2} e-imb={:.2} repl={:.2}",
                cfg.partition.name(),
                pt.vertex_imbalance,
                pt.edge_imbalance,
                pt.replication_factor,
            );
            let mem = &res.report.mem;
            println!(
                "  mem[{}]: {:.2} B/edge shard-max={:.2}MB peak-build={:.2}MB build={:.1}ms",
                mem.storage,
                mem.bytes_per_edge,
                mem.max_shard_bytes as f64 / 1e6,
                mem.peak_builder_bytes as f64 / 1e6,
                mem.build_ms,
            );
            print_fault(&cfg, &res.report);
            if validate {
                println!("validation: OK");
            }
        }
        "mutate" => {
            let algo = args.flag("algo").unwrap_or("sssp");
            let p = args.flag_or("p", *cfg.localities.last().unwrap_or(&4))?;
            let res = coordinator::run_mutate(&cfg, p, algo, validate)?;
            let u = &res.report.update;
            println!(
                "mutate[{}] {} p={p}: batch={} applied={} retracted={} \
                 (frac={} inserts={:.0}%)",
                res.algo,
                cfg.graph_name(),
                u.batch_edges,
                u.applied,
                u.retracted,
                cfg.mutate_frac,
                cfg.mutate_inserts * 100.0,
            );
            println!(
                "  routing: envelopes={} items={}  invalidation: tainted={} reseeded={}",
                u.route_envelopes, u.route_items, u.tainted, u.reseeded,
            );
            println!(
                "  incremental: relax={} envs={} makespan={} (wall {})",
                u.reconverge_relaxations,
                u.reconverge_envelopes,
                fmt_us(u.reconverge_makespan_us),
                fmt_us(u.reconverge_wall_us),
            );
            println!(
                "  full rerun:  relax={} envs={} makespan={} (wall {})  relax saving={:.2}x",
                res.full.work.relaxations,
                res.full.net.envelopes,
                fmt_us(res.full.makespan_us),
                fmt_us(res.full.wall_us),
                res.full.work.relaxations as f64 / u.reconverge_relaxations.max(1) as f64,
            );
            print_fault(&cfg, &res.report);
            if validate {
                println!("validation: OK");
            }
        }
        "fig1" => {
            let (table, _) = experiment::fig1_bfs(&cfg)?;
            print!("{}", table.render());
            if let Some(out) = args.flag("out") {
                table.write_csv(out)?;
                println!("wrote {out}");
            }
        }
        "fig2" => {
            let (table, _) = experiment::fig2_pagerank(&cfg)?;
            print!("{}", table.render());
            if let Some(out) = args.flag("out") {
                table.write_csv(out)?;
                println!("wrote {out}");
            }
        }
        "ablations" => {
            // (file stem, runner) pairs so --json can name its outputs;
            // each table prints (and persists) as soon as it completes.
            type Runner = Box<dyn Fn(&Config) -> Result<nwgraph_hpx::coordinator::Table>>;
            let large = args.switch("large");
            let tables: [(&str, Runner); 11] = [
                ("a1_aggregation", Box::new(experiment::ablation_aggregation)),
                ("a2_chunking", Box::new(experiment::ablation_adaptive_chunk)),
                ("a4_flush_policy", Box::new(experiment::ablation_flush_policy)),
                ("a5_delta_stepping", Box::new(experiment::ablation_delta_stepping)),
                ("a6_partition_schemes", Box::new(experiment::ablation_partition_schemes)),
                ("a7_adaptive_coalescing", Box::new(experiment::ablation_adaptive_coalescing)),
                ("a8_query_serving", Box::new(experiment::ablation_query_serving)),
                ("a9_scale_sweep", Box::new(move |c: &Config| {
                    experiment::ablation_scale_sweep(c, large)
                })),
                ("a10_incremental", Box::new(experiment::ablation_incremental)),
                ("a11_fault_injection", Box::new(experiment::ablation_fault_injection)),
                ("extensions", Box::new(experiment::extensions)),
            ];
            let json = args.switch("json");
            let out_dir = args.flag("out-dir").unwrap_or("bench_out");
            // --only a4,a7,a8,a9,a10,a11: run the prefix-matched subset
            // (CI baselines grab A4+A7+A8+A9+A10+A11 without paying for
            // the whole suite).
            let only: Option<Vec<&str>> =
                args.flag("only").map(|s| s.split(',').map(str::trim).collect());
            if let Some(sel) = &only {
                for pat in sel {
                    anyhow::ensure!(
                        tables.iter().any(|(stem, _)| stem.starts_with(pat)),
                        "--only `{pat}` matches no ablation (stems: {})",
                        tables.iter().map(|(s, _)| *s).collect::<Vec<_>>().join(", ")
                    );
                }
            }
            for (stem, run) in tables {
                if let Some(sel) = &only {
                    if !sel.iter().any(|pat| stem.starts_with(pat)) {
                        continue;
                    }
                }
                let table = run(&cfg)?;
                print!("{}", table.render());
                if json {
                    let path = format!("{out_dir}/{stem}.json");
                    table.write_json(&path)?;
                    println!("wrote {path}");
                }
            }
        }
        "info" => {
            let g = cfg.build_graph()?;
            let out = degree::degree_stats(&degree::out_degrees(&g));
            let ind = degree::degree_stats(&degree::in_degrees(&g));
            println!("{}: n={} m={}", cfg.graph_name(), g.n(), g.m());
            println!("out-degree: min={} max={} mean={:.2}", out.min, out.max, out.mean);
            println!("in-degree:  min={} max={} mean={:.2}", ind.min, ind.max, ind.mean);
            let hist = degree::degree_histogram(&degree::out_degrees(&g));
            for (k, c) in hist.iter().enumerate() {
                println!("  deg 2^{k:<2} {c}");
            }
        }
        other => {
            eprintln!("unknown command `{other}`\n");
            println!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
