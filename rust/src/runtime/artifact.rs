//! Artifact manifest: discovery + shape selection for the AOT modules.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` with one line
//! per lowered module: `kind n_global n_rows max_deg tile_rows file`.
//! The rust side never hard-codes shapes — it parses the manifest and
//! picks the smallest config that covers the shard at hand (padding the
//! shard up to the artifact's static shape).

use std::path::{Path, PathBuf};

use crate::Result;

/// One AOT-lowered module.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// `"pagerank"` or `"bfs"`.
    pub kind: String,
    /// Static global vector length (contribution / frontier input).
    pub n_global: usize,
    /// Static (virtual) row count.
    pub n_rows: usize,
    /// ELL slot width.
    pub max_deg: usize,
    /// Pallas grid tile height (informational).
    pub tile_rows: usize,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
}

/// Parsed manifest plus its base directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut specs = Vec::new();
        for (no, line) in text.lines().enumerate() {
            let s = line.trim();
            if s.is_empty() || s.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = s.split_whitespace().collect();
            if f.len() != 6 {
                anyhow::bail!("manifest line {}: expected 6 fields, got {}", no + 1, f.len());
            }
            specs.push(ArtifactSpec {
                kind: f[0].to_string(),
                n_global: f[1].parse()?,
                n_rows: f[2].parse()?,
                max_deg: f[3].parse()?,
                tile_rows: f[4].parse()?,
                file: f[5].to_string(),
            });
        }
        Ok(Manifest { dir, specs })
    }

    /// All specs.
    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Absolute path of a spec's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Smallest config of `kind` covering `n_global` gather range and
    /// `n_rows` virtual rows (ties broken toward fewer rows, then smaller
    /// max_deg).
    pub fn pick(&self, kind: &str, n_global: usize, n_rows: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| s.kind == kind && s.n_global >= n_global && s.n_rows >= n_rows)
            .min_by_key(|s| (s.n_global, s.n_rows, s.max_deg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# kind n_global n_rows max_deg tile_rows file
pagerank 4096 4096 32 1024 pagerank_g4096_r4096_d32.hlo.txt
pagerank 4096 2048 32 1024 pagerank_g4096_r2048_d32.hlo.txt
pagerank 16384 16384 32 1024 pagerank_g16384_r16384_d32.hlo.txt
bfs 4096 4096 32 1024 bfs_g4096_r4096_d32.hlo.txt
";

    fn manifest() -> Manifest {
        Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap()
    }

    #[test]
    fn parses_all_lines() {
        assert_eq!(manifest().specs().len(), 4);
    }

    #[test]
    fn pick_prefers_smallest_covering_config() {
        let m = manifest();
        let s = m.pick("pagerank", 3000, 1500).unwrap();
        assert_eq!((s.n_global, s.n_rows), (4096, 2048));
        let s = m.pick("pagerank", 3000, 3000).unwrap();
        assert_eq!((s.n_global, s.n_rows), (4096, 4096));
        let s = m.pick("pagerank", 10000, 100).unwrap();
        assert_eq!(s.n_global, 16384);
    }

    #[test]
    fn pick_respects_kind_and_bounds() {
        let m = manifest();
        assert!(m.pick("bfs", 4096, 4096).is_some());
        assert!(m.pick("bfs", 4097, 1).is_none());
        assert!(m.pick("pagerank", 100_000, 1).is_none());
        assert!(m.pick("nope", 1, 1).is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("pagerank 1 2 3", PathBuf::new()).is_err());
        assert!(Manifest::parse("pagerank a b c d e", PathBuf::new()).is_err());
    }
}
