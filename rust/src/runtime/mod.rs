//! PJRT runtime: load and execute the AOT-lowered Pallas/JAX modules.
//!
//! The compile path (`make artifacts`) runs python once; this module is the
//! *only* consumer of its output at runtime. Each `artifacts/*.hlo.txt` is
//! parsed by the `xla` crate's HLO text parser, compiled on the PJRT CPU
//! client, and cached; the PageRank/BFS kernel variants call
//! [`Engine::pagerank_step`] / [`Engine::bfs_level`] from the simulated
//! localities' compute phases. Python never runs on this path.
//!
//! The `xla` crate is not available in the offline build image, so the
//! real engine is gated behind the `xla` cargo feature. Without it,
//! [`Engine`] keeps the same API but `Engine::load` always fails with a
//! clear message — the kernel tests and examples detect the missing
//! artifacts/engine and skip.

pub mod artifact;

use crate::Result;
pub use artifact::{ArtifactSpec, Manifest};

#[cfg(feature = "xla")]
pub use pjrt::Engine;
#[cfg(not(feature = "xla"))]
pub use stub::Engine;

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::Path;

    use super::{ArtifactSpec, Manifest};
    use crate::Result;

    /// A compiled-executable cache over the artifact manifest.
    pub struct Engine {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Engine {
        /// Create a CPU PJRT engine over `artifact_dir`.
        pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Engine> {
            let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
            let manifest = Manifest::load(artifact_dir)?;
            Ok(Engine { client, manifest, cache: HashMap::new() })
        }

        /// The manifest in use.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn executable(&mut self, spec: &ArtifactSpec) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(&spec.file) {
                let path = self.manifest.path_of(spec);
                let proto = xla::HloModuleProto::from_text_file(&path).map_err(to_anyhow)?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self.client.compile(&comp).map_err(to_anyhow)?;
                self.cache.insert(spec.file.clone(), exe);
            }
            Ok(&self.cache[&spec.file])
        }

        /// Pick + compile the best artifact for `kind` covering the given
        /// shard geometry. Returns the chosen spec.
        pub fn prepare(
            &mut self,
            kind: &str,
            n_global: usize,
            n_rows: usize,
        ) -> Result<ArtifactSpec> {
            let spec = self
                .manifest
                .pick(kind, n_global, n_rows)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no {kind} artifact covers n_global={n_global}, n_rows={n_rows}; \
                         re-run `make artifacts` with a larger shape registry"
                    )
                })?
                .clone();
            self.executable(&spec)?;
            Ok(spec)
        }

        /// One local PageRank rank-update on the AOT module:
        /// inputs must already be padded to `spec` shapes
        /// (`contrib: f32[n_global]`, `rank_old: f32[n_rows]`,
        /// `cols: i32[n_rows * max_deg]`, `mask: f32[n_rows * max_deg]`,
        /// `row_map: i32[n_rows]` mapping virtual rows to owned rows).
        /// Returns `(rank_new: f32[n_rows], l1_delta)`.
        #[allow(clippy::too_many_arguments)]
        pub fn pagerank_step(
            &mut self,
            spec: &ArtifactSpec,
            contrib: &[f32],
            rank_old: &[f32],
            cols: &[i32],
            mask: &[f32],
            row_map: &[i32],
            base: f32,
            alpha: f32,
        ) -> Result<(Vec<f32>, f32)> {
            debug_assert_eq!(contrib.len(), spec.n_global);
            debug_assert_eq!(rank_old.len(), spec.n_rows);
            debug_assert_eq!(cols.len(), spec.n_rows * spec.max_deg);
            debug_assert_eq!(mask.len(), cols.len());
            debug_assert_eq!(row_map.len(), spec.n_rows);
            let r = spec.n_rows as i64;
            let d = spec.max_deg as i64;
            let args = [
                xla::Literal::vec1(contrib),
                xla::Literal::vec1(rank_old),
                xla::Literal::vec1(cols).reshape(&[r, d]).map_err(to_anyhow)?,
                xla::Literal::vec1(mask).reshape(&[r, d]).map_err(to_anyhow)?,
                xla::Literal::vec1(row_map),
                xla::Literal::vec1(&[base]),
                xla::Literal::vec1(&[alpha]),
            ];
            let exe = self.executable(spec)?;
            let result = exe.execute::<xla::Literal>(&args).map_err(to_anyhow)?[0][0]
                .to_literal_sync()
                .map_err(to_anyhow)?;
            let parts = result.to_tuple().map_err(to_anyhow)?;
            anyhow::ensure!(parts.len() == 2, "pagerank artifact returned {} outputs", parts.len());
            let mut it = parts.into_iter();
            let rank_new = it.next().unwrap().to_vec::<f32>().map_err(to_anyhow)?;
            let delta = it.next().unwrap().to_vec::<f32>().map_err(to_anyhow)?[0];
            Ok((rank_new, delta))
        }

        /// One local BFS level expansion on the AOT module. Shapes as in
        /// [`Engine::pagerank_step`] with `frontier: f32[n_global]`,
        /// `visited: f32[n_rows]`. Returns `(next_frontier, parents)` over
        /// the padded rows.
        pub fn bfs_level(
            &mut self,
            spec: &ArtifactSpec,
            frontier: &[f32],
            visited: &[f32],
            cols: &[i32],
            mask: &[f32],
        ) -> Result<(Vec<f32>, Vec<i32>)> {
            debug_assert_eq!(frontier.len(), spec.n_global);
            debug_assert_eq!(visited.len(), spec.n_rows);
            let r = spec.n_rows as i64;
            let d = spec.max_deg as i64;
            let args = [
                xla::Literal::vec1(frontier),
                xla::Literal::vec1(visited),
                xla::Literal::vec1(cols).reshape(&[r, d]).map_err(to_anyhow)?,
                xla::Literal::vec1(mask).reshape(&[r, d]).map_err(to_anyhow)?,
            ];
            let exe = self.executable(spec)?;
            let result = exe.execute::<xla::Literal>(&args).map_err(to_anyhow)?[0][0]
                .to_literal_sync()
                .map_err(to_anyhow)?;
            let parts = result.to_tuple().map_err(to_anyhow)?;
            anyhow::ensure!(parts.len() == 2, "bfs artifact returned {} outputs", parts.len());
            let mut it = parts.into_iter();
            let next = it.next().unwrap().to_vec::<f32>().map_err(to_anyhow)?;
            let parents = it.next().unwrap().to_vec::<i32>().map_err(to_anyhow)?;
            Ok((next, parents))
        }
    }

    fn to_anyhow(e: xla::Error) -> anyhow::Error {
        anyhow::anyhow!("xla: {e}")
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use std::convert::Infallible;
    use std::path::Path;

    use super::{ArtifactSpec, Manifest};
    use crate::Result;

    /// API-compatible stand-in for the PJRT engine when the `xla` feature
    /// is off. It can never be constructed ([`Engine::load`] always errs),
    /// so every method body is statically unreachable.
    pub struct Engine {
        void: Infallible,
    }

    impl Engine {
        /// Always fails: the PJRT path needs the `xla` feature.
        pub fn load(_artifact_dir: impl AsRef<Path>) -> Result<Engine> {
            anyhow::bail!(
                "built without the `xla` feature: the PJRT kernel path is \
                 unavailable (rebuild with `--features xla` and the xla crate)"
            )
        }

        /// The manifest in use.
        pub fn manifest(&self) -> &Manifest {
            match self.void {}
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            match self.void {}
        }

        /// See the `xla`-enabled engine.
        pub fn prepare(
            &mut self,
            _kind: &str,
            _n_global: usize,
            _n_rows: usize,
        ) -> Result<ArtifactSpec> {
            match self.void {}
        }

        /// See the `xla`-enabled engine.
        #[allow(clippy::too_many_arguments)]
        pub fn pagerank_step(
            &mut self,
            _spec: &ArtifactSpec,
            _contrib: &[f32],
            _rank_old: &[f32],
            _cols: &[i32],
            _mask: &[f32],
            _row_map: &[i32],
            _base: f32,
            _alpha: f32,
        ) -> Result<(Vec<f32>, f32)> {
            match self.void {}
        }

        /// See the `xla`-enabled engine.
        pub fn bfs_level(
            &mut self,
            _spec: &ArtifactSpec,
            _frontier: &[f32],
            _visited: &[f32],
            _cols: &[i32],
            _mask: &[f32],
        ) -> Result<(Vec<f32>, Vec<i32>)> {
            match self.void {}
        }
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests live in `rust/tests/kernel_artifacts.rs` (they need the
    //! artifacts built by `make artifacts`); here we only check error paths
    //! that need no artifacts.
    use super::*;

    #[test]
    fn load_fails_without_manifest() {
        assert!(Engine::load("/definitely/not/a/dir").is_err());
    }
}
