//! Query serving: answer a stream of point-to-point `s → t` queries on a
//! distributed graph instead of running one-shot full-graph analytics.
//!
//! This is the ROADMAP's latency-bound regime ("serve heavy traffic"):
//! the asynchronous many-task substrate hides latency for irregular
//! point-to-point work, and the serving layer stacks three amortizations
//! on top of it —
//!
//! 1. **Landmark oracle** ([`oracle`]): `k` high-degree landmarks are
//!    precomputed once (batched multi-source SSSP waves); covered queries
//!    become table lookups.
//! 2. **Hot-source LRU cache**: a full shortest-path tree per recently
//!    queried source; repeat sources answer locally.
//! 3. **Batched waves** ([`wave`]): concurrent uncovered queries share
//!    width-`B` multi-source SSSP waves through the existing aggregator —
//!    `waves` ends far below `queries`.
//!
//! Every path is exact: an oracle or cache hit never changes an answer,
//! only its latency (the covered-vs-uncovered parity property in
//! `tests/serve_props.rs`). The one deliberate exception is **graceful
//! degradation** under overload or faults: when a window misses its
//! [`ServeParams::deadline_us`] (or a wave stays fault-suspect past its
//! one bounded retry), the remaining queries answer with landmark
//! triangle-inequality *bounds* — always flagged as [`Answer::Approx`],
//! never silently passed off as exact. Results carry
//! [`QueryStats`](crate::amt::QueryStats) in the run's
//! [`SimReport`] — hits, waves, qps, and the wall-clock latency
//! distribution (real end-to-end time under `runtime=threads`).
//!
//! All waves run on the generic mirror-aware async engine; serving works
//! under every partition scheme, vertex cuts included, and never calls
//! `engine::require_mirror_free`.

pub mod oracle;
pub mod wave;

use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::time::Instant;

use crate::algorithms::sssp;
use crate::amt::{FlushPolicy, QueryStats, SimConfig, SimReport};
use crate::graph::generators::SplitMix64;
use crate::graph::{Csr, DistGraph, VertexId};
use crate::Result;

pub use oracle::LandmarkOracle;
pub use wave::{run_wave, MultiSourceSssp, WaveResult};

/// Serving knobs (config keys `serve_*`).
#[derive(Debug, Clone)]
pub struct ServeParams {
    /// Queries in the generated stream.
    pub queries: usize,
    /// Landmarks to precompute (`0` = no oracle tables).
    pub landmarks: usize,
    /// Hot-source LRU cache capacity, in source trees (`0` = disabled).
    pub cache: usize,
    /// Multi-source wave width: uncovered sources per engine run.
    pub batch: usize,
    /// Master switch for the landmark oracle (tables + covered answers).
    pub oracle: bool,
    /// Per-window latency deadline in host wall-clock µs (`0` = none).
    /// Once a window has spent its deadline, no further exact waves are
    /// launched for it; the remaining uncovered queries degrade to
    /// landmark triangle-inequality bounds ([`Answer::Approx`]).
    pub deadline_us: f64,
    /// Stream seed (query endpoints and kinds).
    pub seed: u64,
}

impl Default for ServeParams {
    fn default() -> Self {
        ServeParams {
            queries: 1000,
            landmarks: 8,
            cache: 32,
            batch: 16,
            oracle: true,
            deadline_us: 0.0,
            seed: 42,
        }
    }
}

/// One point-to-point query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Query {
    /// What to compute about the pair.
    pub kind: QueryKind,
    /// Source vertex.
    pub s: VertexId,
    /// Target vertex.
    pub t: VertexId,
}

/// Query flavors of the generated stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryKind {
    /// Shortest-path distance `d(s, t)`.
    Distance,
    /// Distance plus the vertex sequence of one shortest path.
    Path,
    /// Distance rank of `t` from `s`: how many vertices are strictly
    /// closer to `s` (see [`rank_of`]).
    Rank,
}

/// Answer to one [`Query`]. Distances are `f32::INFINITY` when `t` is
/// unreachable from `s` (and the path is `None`).
#[derive(Debug, Clone, PartialEq)]
pub enum Answer {
    /// `d(s, t)`.
    Distance(f32),
    /// `d(s, t)` plus one shortest path `s, ..., t`.
    Path {
        /// The path's total weight.
        dist: f32,
        /// Vertex sequence (`None` = unreachable).
        path: Option<Vec<VertexId>>,
    },
    /// Number of vertices strictly closer to `s` than `t`.
    Rank(u32),
    /// Degraded answer: landmark triangle-inequality *bounds* on
    /// `d(s, t)` instead of the exact value, returned when the query's
    /// window missed its deadline (or its wave stayed fault-suspect past
    /// the bounded retry). Always flagged — callers can tell an
    /// approximation from an exact answer by the variant alone. `hi` is
    /// `f32::INFINITY` when the pair is proven disconnected or no
    /// landmark covers it.
    Approx {
        /// Lower bound on `d(s, t)`.
        lo: f32,
        /// Upper bound on `d(s, t)`.
        hi: f32,
    },
}

/// Outcome of one serve run.
#[derive(Debug)]
pub struct ServeResult {
    /// The generated stream, in arrival order.
    pub queries: Vec<Query>,
    /// One answer per query, same order.
    pub answers: Vec<Answer>,
    /// Merged runtime report of the precompute and all query waves, with
    /// [`SimReport::query`] stamped. `makespan_us`/`wall_us` accumulate
    /// across engine runs; `wall_us` covers the whole serve call.
    pub report: SimReport,
}

/// Distance rank: how many vertices are strictly closer to the source
/// than `t`, given the source's full distance vector. An unreachable `t`
/// ranks below every reached vertex (the count of finite distances).
pub fn rank_of(dist: &[f32], t: VertexId) -> u32 {
    let dt = dist[t as usize];
    if dt.is_finite() {
        dist.iter().filter(|d| **d < dt).count() as u32
    } else {
        dist.iter().filter(|d| d.is_finite()).count() as u32
    }
}

/// Generate a query stream: sources are skewed toward a small hot pool
/// (half the stream) so the LRU cache has something to catch, targets are
/// uniform, and kinds mix distance (5/8), path (2/8), and rank (1/8).
pub fn generate_queries(n: usize, count: usize, seed: u64) -> Vec<Query> {
    assert!(n > 0, "cannot query an empty graph");
    let mut rng = SplitMix64::new(seed);
    let hot: Vec<VertexId> =
        (0..8).map(|_| rng.below(n as u64) as VertexId).collect();
    (0..count)
        .map(|_| {
            let s = if rng.below(2) == 0 {
                hot[rng.below(hot.len() as u64) as usize]
            } else {
                rng.below(n as u64) as VertexId
            };
            let t = rng.below(n as u64) as VertexId;
            let kind = match rng.below(8) {
                0 | 1 => QueryKind::Path,
                2 => QueryKind::Rank,
                _ => QueryKind::Distance,
            };
            Query { kind, s, t }
        })
        .collect()
}

/// One cached shortest-path tree (distances + parents for a source).
#[derive(Debug)]
struct SourceTree {
    dist: Vec<f32>,
    parents: Vec<i64>,
}

/// Hot-source LRU: most-recently-used source trees, capacity in trees.
struct SourceCache {
    cap: usize,
    order: VecDeque<VertexId>,
    map: HashMap<VertexId, Rc<SourceTree>>,
}

impl SourceCache {
    fn new(cap: usize) -> Self {
        SourceCache { cap, order: VecDeque::new(), map: HashMap::new() }
    }

    fn get(&mut self, s: VertexId) -> Option<Rc<SourceTree>> {
        let tree = self.map.get(&s)?.clone();
        // Refresh recency.
        if let Some(pos) = self.order.iter().position(|&v| v == s) {
            self.order.remove(pos);
        }
        self.order.push_back(s);
        Some(tree)
    }

    fn insert(&mut self, s: VertexId, tree: Rc<SourceTree>) {
        if self.cap == 0 {
            return;
        }
        if self.map.insert(s, tree).is_none() {
            self.order.push_back(s);
            if self.order.len() > self.cap {
                if let Some(evict) = self.order.pop_front() {
                    self.map.remove(&evict);
                }
            }
        } else if let Some(pos) = self.order.iter().position(|&v| v == s) {
            self.order.remove(pos);
            self.order.push_back(s);
        }
    }
}

fn answer_from_tree(q: &Query, tree: &SourceTree) -> Answer {
    match q.kind {
        QueryKind::Distance => Answer::Distance(tree.dist[q.t as usize]),
        QueryKind::Path => Answer::Path {
            dist: tree.dist[q.t as usize],
            path: sssp::recover_path(&tree.parents, q.s, q.t),
        },
        QueryKind::Rank => Answer::Rank(rank_of(&tree.dist, q.t)),
    }
}

/// Fault detector for one wave result: every source must see itself at
/// distance zero. A source that lost its own seed (dropped envelopes
/// under `reliability=none`, an unrecovered crash) fails this check and
/// the wave is re-run once before its queries degrade.
fn wave_sane(srcs: &[VertexId], dist: &[Vec<f32>]) -> bool {
    srcs.iter().zip(dist).all(|(&s, d)| d[s as usize] == 0.0)
}

fn answer_from_oracle(oracle: &LandmarkOracle, q: &Query) -> Option<Answer> {
    match q.kind {
        QueryKind::Distance => oracle.exact_distance(q.s, q.t).map(Answer::Distance),
        QueryKind::Path => {
            let path = oracle.exact_path(q.s, q.t)?;
            let dist = oracle.exact_distance(q.s, q.t)?;
            Some(Answer::Path { dist, path })
        }
        QueryKind::Rank => {
            if q.s == q.t {
                return Some(Answer::Rank(0));
            }
            let i = oracle.landmark_index(q.s)?;
            Some(Answer::Rank(rank_of(&oracle.tables[i], q.t)))
        }
    }
}

/// Accumulate a second engine run into a serve-level report: traffic,
/// barriers, busy time, and modeled makespan add up; phase segments
/// concatenate.
pub(crate) fn merge_reports(into: &mut SimReport, other: &SimReport) {
    into.n_localities = into.n_localities.max(other.n_localities);
    into.makespan_us += other.makespan_us;
    into.barriers += other.barriers;
    into.events += other.events;
    into.net.merge(&other.net);
    if into.busy_us.len() < other.busy_us.len() {
        into.busy_us.resize(other.busy_us.len(), 0.0);
    }
    for (a, b) in into.busy_us.iter_mut().zip(&other.busy_us) {
        *a += b;
    }
    if into.per_locality_net.len() < other.per_locality_net.len() {
        into.per_locality_net.resize(other.per_locality_net.len(), Default::default());
    }
    for (a, b) in into.per_locality_net.iter_mut().zip(&other.per_locality_net) {
        a.merge(b);
    }
    into.agg.merge(&other.agg);
    into.agg_master.merge(&other.agg_master);
    into.agg_mirror.merge(&other.agg_mirror);
    into.work.merge(&other.work);
    into.update.merge(&other.update);
    into.wall_us += other.wall_us;
    into.phase_wall_us.extend_from_slice(&other.phase_wall_us);
}

/// A zeroed report for serve runs that never touched an engine (e.g. an
/// all-covered stream with the oracle prebuilt elsewhere).
fn empty_report(p: u32) -> SimReport {
    let mut r = SimReport::new(p);
    r.busy_us = vec![0.0; p as usize];
    r.per_locality_net = vec![Default::default(); p as usize];
    r
}

/// Interpolation-free percentile of an ascending-sorted slice
/// (nearest-rank). Empty input yields 0.
fn percentile(sorted_us: &[f64], q: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * q).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

/// Serve a generated query stream. Precomputes the landmark oracle (when
/// enabled), then answers queries in arrival windows: covered queries
/// resolve from cache/tables immediately, the uncovered remainder batches
/// into shared multi-source waves. Per-query latency is host wall-clock
/// from window arrival to answer; `qps` covers the serving phase
/// (precompute excluded — it is a build step, reported in `wall_us`).
///
/// The `DistGraph` must be built from the weighted `g`. For the oracle's
/// triangle bounds `g` must carry a symmetric metric (see
/// [`oracle`] module docs) — the coordinator builds one with
/// [`with_symmetric_random_weights`](crate::graph::generators::with_symmetric_random_weights).
pub fn run(
    g: &Csr,
    dist_graph: &DistGraph,
    params: &ServeParams,
    policy: FlushPolicy,
    cfg: SimConfig,
) -> ServeResult {
    let t0 = Instant::now();
    let p = dist_graph.shards.len() as u32;
    let batch = params.batch.max(1);

    let k = if params.oracle { params.landmarks } else { 0 };
    let (oracle, precompute_report) =
        LandmarkOracle::build(g, dist_graph, k, batch, policy, &cfg);
    let mut report = precompute_report.unwrap_or_else(|| empty_report(p));

    let queries = generate_queries(g.n(), params.queries, params.seed);
    let mut answers: Vec<Option<Answer>> = vec![None; queries.len()];
    let mut latencies_us = vec![0.0f64; queries.len()];
    let mut stats = QueryStats { queries: queries.len() as u64, ..QueryStats::default() };
    let mut cache = SourceCache::new(params.cache);

    let serve_t0 = Instant::now();
    let window = batch * 4;
    for (w, chunk) in queries.chunks(window).enumerate() {
        let base = w * window;
        let round_t0 = Instant::now();
        let mut pending: Vec<usize> = Vec::new();
        for (j, q) in chunk.iter().enumerate() {
            let idx = base + j;
            if let Some(tree) = cache.get(q.s) {
                answers[idx] = Some(answer_from_tree(q, &tree));
                stats.cache_hits += 1;
            } else if params.oracle {
                if let Some(ans) = answer_from_oracle(&oracle, q) {
                    answers[idx] = Some(ans);
                    stats.oracle_hits += 1;
                } else {
                    pending.push(idx);
                }
            } else {
                pending.push(idx);
            }
            if answers[idx].is_some() {
                latencies_us[idx] = round_t0.elapsed().as_secs_f64() * 1e6;
            }
        }

        // Batch the uncovered remainder into shared multi-source waves.
        let mut uncovered: Vec<VertexId> = Vec::new();
        for &idx in &pending {
            let s = queries[idx].s;
            if !uncovered.contains(&s) {
                uncovered.push(s);
            }
        }
        // Round-local trees: guaranteed to survive until every pending
        // query is answered even when the LRU capacity is smaller than
        // the round's source set.
        let mut round_trees: HashMap<VertexId, Rc<SourceTree>> = HashMap::new();
        for src_chunk in uncovered.chunks(batch) {
            // Graceful degradation: launching another exact wave past the
            // window's deadline would blow the latency target for every
            // query queued behind it. Stop waving; the remainder degrades
            // to landmark bounds below.
            if params.deadline_us > 0.0
                && round_t0.elapsed().as_secs_f64() * 1e6 >= params.deadline_us
            {
                break;
            }
            let mut res = run_wave(g, dist_graph, src_chunk, policy, cfg.clone());
            stats.waves += 1;
            merge_reports(&mut report, &res.report);
            // Fault-suspect result: one bounded retry, then give up and
            // let the chunk's queries degrade.
            if !wave_sane(src_chunk, &res.dist) {
                stats.retries += 1;
                res = run_wave(g, dist_graph, src_chunk, policy, cfg.clone());
                stats.waves += 1;
                merge_reports(&mut report, &res.report);
            }
            if wave_sane(src_chunk, &res.dist) {
                for ((&s, dist), parents) in
                    src_chunk.iter().zip(res.dist).zip(res.parents)
                {
                    let tree = Rc::new(SourceTree { dist, parents });
                    cache.insert(s, tree.clone());
                    round_trees.insert(s, tree);
                }
            }
            // Answer every pending query this wave unblocked, stamping
            // its latency now (arrival → answer, real wall-clock).
            for &idx in &pending {
                if answers[idx].is_some() {
                    continue;
                }
                let q = &queries[idx];
                if let Some(tree) = round_trees.get(&q.s) {
                    answers[idx] = Some(answer_from_tree(q, tree));
                    latencies_us[idx] = round_t0.elapsed().as_secs_f64() * 1e6;
                }
            }
        }
        // Degraded path: whatever the deadline (or a twice-unsane wave)
        // left unanswered gets landmark triangle-inequality bounds,
        // flagged approximate. With no landmarks the bounds are the
        // vacuous `[0, +inf)` — still honest, still flagged.
        for &idx in &pending {
            if answers[idx].is_none() {
                let q = &queries[idx];
                let (lo, hi) = oracle.bounds(q.s, q.t);
                answers[idx] = Some(Answer::Approx { lo, hi });
                latencies_us[idx] = round_t0.elapsed().as_secs_f64() * 1e6;
                stats.degraded += 1;
            }
        }
    }
    let serve_secs = serve_t0.elapsed().as_secs_f64();

    let answers: Vec<Answer> = answers
        .into_iter()
        .map(|a| a.expect("every query is answered by its round"))
        .collect();
    stats.qps = if serve_secs > 0.0 { queries.len() as f64 / serve_secs } else { 0.0 };
    let mut sorted = latencies_us.clone();
    sorted.sort_by(f64::total_cmp);
    stats.p50_us = percentile(&sorted, 0.50);
    stats.p99_us = percentile(&sorted, 0.99);

    report.partition = dist_graph.partition_stats();
    report.mem = dist_graph.mem_stats();
    report.query = stats;
    report.wall_us = t0.elapsed().as_secs_f64() * 1e6;
    ServeResult { queries, answers, report }
}

/// Validate every answer against the sequential Dijkstra oracle
/// (memoized per distinct source). Distances and path weights must agree
/// within `1e-3`; paths must be edge-valid walks `s, ..., t`; ranks must
/// fall inside the float-tolerance bracket around the oracle rank.
pub fn validate(g: &Csr, queries: &[Query], answers: &[Answer]) -> Result<()> {
    anyhow::ensure!(queries.len() == answers.len(), "answer count mismatch");
    let mut truth: HashMap<VertexId, Vec<f32>> = HashMap::new();
    for (q, a) in queries.iter().zip(answers) {
        let want =
            truth.entry(q.s).or_insert_with(|| sssp::dijkstra(g, q.s));
        let wd = want[q.t as usize];
        let check_dist = |got: f32| -> Result<()> {
            let ok = (got.is_infinite() && wd.is_infinite()) || (got - wd).abs() < 1e-3;
            anyhow::ensure!(ok, "query {q:?}: distance {got} vs oracle {wd}");
            Ok(())
        };
        match a {
            Answer::Distance(got) => check_dist(*got)?,
            Answer::Path { dist, path } => {
                check_dist(*dist)?;
                match path {
                    None => anyhow::ensure!(
                        wd.is_infinite(),
                        "query {q:?}: no path but oracle distance {wd}"
                    ),
                    Some(path) => {
                        anyhow::ensure!(
                            path.first() == Some(&q.s) && path.last() == Some(&q.t),
                            "query {q:?}: path endpoints {path:?}"
                        );
                        let w = sssp::path_weight(g, path).ok_or_else(|| {
                            anyhow::anyhow!("query {q:?}: path uses a non-edge")
                        })?;
                        anyhow::ensure!(
                            (w - dist).abs() < 1e-3,
                            "query {q:?}: path weighs {w}, reported {dist}"
                        );
                    }
                }
            }
            Answer::Approx { lo, hi } => {
                // A degraded answer never claims exactness; its contract
                // is the bound sandwich around the true distance.
                anyhow::ensure!(lo <= hi, "query {q:?}: inverted bounds [{lo}, {hi}]");
                if wd.is_finite() {
                    anyhow::ensure!(
                        *lo <= wd + 1e-2 && *hi >= wd - 1e-2,
                        "query {q:?}: oracle {wd} outside bounds [{lo}, {hi}]"
                    );
                } else {
                    anyhow::ensure!(
                        hi.is_infinite(),
                        "query {q:?}: finite upper bound {hi} on an unreachable pair"
                    );
                }
            }
            Answer::Rank(got) => {
                // Strict-less counting is float-sensitive near ties, so
                // bracket the oracle rank with a ±5e-3 margin.
                let (lo, hi) = if wd.is_finite() {
                    (
                        want.iter().filter(|d| **d < wd - 5e-3).count() as u32,
                        want.iter().filter(|d| **d < wd + 5e-3).count() as u32,
                    )
                } else {
                    let r = want.iter().filter(|d| d.is_finite()).count() as u32;
                    (r, r)
                };
                anyhow::ensure!(
                    (lo..=hi).contains(got),
                    "query {q:?}: rank {got} outside oracle bracket [{lo}, {hi}]"
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    fn serve_graph(scale: u32, seed: u64) -> Csr {
        generators::with_symmetric_random_weights(
            &generators::kron(scale, 5, seed),
            1.0,
            10.0,
            seed + 1,
        )
    }

    fn small_params() -> ServeParams {
        ServeParams {
            queries: 64,
            landmarks: 4,
            cache: 8,
            batch: 4,
            oracle: true,
            deadline_us: 0.0,
            seed: 7,
        }
    }

    #[test]
    fn missed_deadline_degrades_to_flagged_bounds() {
        let g = serve_graph(7, 3);
        let d = DistGraph::block(&g, 4);
        // A one-femtosecond budget: every window is over-deadline before
        // its first wave, so all uncovered queries must degrade.
        let params = ServeParams { deadline_us: 1e-9, ..small_params() };
        let res = run(&g, &d, &params, FlushPolicy::Adaptive, det());
        // Degraded answers still validate: the bound sandwich is checked
        // against the sequential Dijkstra oracle.
        validate(&g, &res.queries, &res.answers).unwrap();
        let q = res.report.query;
        assert!(q.degraded > 0, "nothing degraded: {q:?}");
        assert_eq!(
            q.degraded as usize,
            res.answers.iter().filter(|a| matches!(a, Answer::Approx { .. })).count(),
            "degraded count must equal flagged answers"
        );
        // Covered queries stay exact even under the deadline: the oracle
        // and cache answer before the budget check ever runs.
        assert_eq!(q.oracle_hits + q.cache_hits + q.degraded, q.queries, "{q:?}");
        // No deadline → no degradation on the same stream.
        let exact = run(&g, &d, &small_params(), FlushPolicy::Adaptive, det());
        assert_eq!(exact.report.query.degraded, 0);
        assert!(exact.answers.iter().all(|a| !matches!(a, Answer::Approx { .. })));
    }

    #[test]
    fn wave_sanity_detector() {
        // Sources must see themselves at distance zero; anything else is
        // fault-suspect and triggers the bounded retry.
        assert!(wave_sane(&[0, 2], &[vec![0.0, 5.0, 9.0], vec![3.0, 1.0, 0.0]]));
        assert!(!wave_sane(&[0], &[vec![0.5, 0.0]]));
        assert!(!wave_sane(&[1], &[vec![0.0, f32::INFINITY]]));
        assert!(wave_sane(&[], &[]));
    }

    #[test]
    fn serve_answers_validate_and_batch() {
        let g = serve_graph(7, 3);
        let d = DistGraph::block(&g, 4);
        let res = run(&g, &d, &small_params(), FlushPolicy::Adaptive, det());
        validate(&g, &res.queries, &res.answers).unwrap();
        let q = res.report.query;
        assert_eq!(q.queries, 64);
        assert!(q.oracle_hits + q.cache_hits > 0, "no covered queries: {q:?}");
        assert!(q.waves < q.queries, "no batching win: {q:?}");
        // Counter invariants are clock-independent; a fast machine may
        // legitimately measure 0us on a cached query, so the strictly
        // positive latency pins are opt-in via NWGRAPH_STRICT_TIMING=1.
        assert!(q.qps >= 0.0 && q.p50_us >= 0.0 && q.p99_us >= q.p50_us, "{q:?}");
        assert!(res.report.wall_us >= 0.0);
        if std::env::var("NWGRAPH_STRICT_TIMING").as_deref() == Ok("1") {
            assert!(q.qps > 0.0 && q.p50_us > 0.0 && res.report.wall_us > 0.0, "{q:?}");
        }
    }

    #[test]
    fn serve_works_under_vertex_cut() {
        // Regression (satellite 2): serve routes through the mirror-aware
        // generic engines and must not inherit require_mirror_free.
        let g = serve_graph(7, 13);
        let d = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 4));
        assert!(d.has_mirrors(), "kron@4 vertex cut should mirror");
        let res = run(&g, &d, &small_params(), FlushPolicy::Adaptive, det());
        validate(&g, &res.queries, &res.answers).unwrap();
        assert!(res.report.query.waves > 0);
    }

    #[test]
    fn oracle_and_cache_never_change_answers() {
        // Covered-vs-uncovered parity: the same stream answered with the
        // oracle and cache on, off, and shrunk must agree exactly.
        let g = serve_graph(6, 29);
        let d = DistGraph::block(&g, 2);
        let base = small_params();
        let res_on = run(&g, &d, &base, FlushPolicy::Adaptive, det());
        for params in [
            ServeParams { oracle: false, ..base.clone() },
            ServeParams { cache: 0, ..base.clone() },
            ServeParams { oracle: false, cache: 0, batch: 1, ..base.clone() },
        ] {
            let res = run(&g, &d, &params, FlushPolicy::Adaptive, det());
            assert_eq!(res.queries, res_on.queries);
            for (i, (a, b)) in res_on.answers.iter().zip(&res.answers).enumerate() {
                assert!(
                    answers_close(a, b),
                    "query {i} {:?}: {a:?} vs {b:?} ({params:?})",
                    res.queries[i]
                );
            }
        }
    }

    fn close_f(a: f32, b: f32) -> bool {
        (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3
    }

    fn answers_close(a: &Answer, b: &Answer) -> bool {
        match (a, b) {
            (Answer::Distance(x), Answer::Distance(y)) => close_f(*x, *y),
            // Paths may legitimately differ between equally-short routes;
            // parity is on the reported distance (validate() checks the
            // walks themselves).
            (Answer::Path { dist: x, path: px }, Answer::Path { dist: y, path: py }) => {
                close_f(*x, *y) && px.is_some() == py.is_some()
            }
            (Answer::Rank(x), Answer::Rank(y)) => x.abs_diff(*y) <= 2,
            _ => false,
        }
    }

    #[test]
    fn cache_counts_repeat_sources() {
        let g = serve_graph(6, 31);
        let d = DistGraph::block(&g, 2);
        // No oracle: every first-time source waves, repeats must hit the
        // cache (the generator's hot pool guarantees repeats).
        let params = ServeParams {
            queries: 96,
            landmarks: 0,
            cache: 64,
            batch: 4,
            oracle: false,
            seed: 5,
        };
        let res = run(&g, &d, &params, FlushPolicy::Adaptive, det());
        validate(&g, &res.queries, &res.answers).unwrap();
        let q = res.report.query;
        assert_eq!(q.oracle_hits, 0);
        assert!(q.cache_hits > 0, "{q:?}");
        assert!(q.waves > 0 && q.waves < q.queries, "{q:?}");
    }

    #[test]
    fn generated_stream_is_deterministic_and_skewed() {
        let a = generate_queries(100, 200, 9);
        let b = generate_queries(100, 200, 9);
        assert_eq!(a, b);
        assert_ne!(a, generate_queries(100, 200, 10));
        let mut by_source: HashMap<VertexId, usize> = HashMap::new();
        for q in &a {
            *by_source.entry(q.s).or_default() += 1;
        }
        let max_repeat = by_source.values().max().unwrap();
        assert!(*max_repeat > 5, "hot pool should repeat sources: {max_repeat}");
        assert!(a.iter().any(|q| q.kind == QueryKind::Path));
        assert!(a.iter().any(|q| q.kind == QueryKind::Rank));
        assert!(a.iter().any(|q| q.kind == QueryKind::Distance));
    }

    #[test]
    fn rank_of_counts_strictly_closer() {
        let dist = [0.0f32, 2.0, 1.0, f32::INFINITY, 2.0];
        assert_eq!(rank_of(&dist, 0), 0);
        assert_eq!(rank_of(&dist, 2), 1); // only the source is closer
        assert_eq!(rank_of(&dist, 1), 2); // source + v2; the tie at v4 is not
        assert_eq!(rank_of(&dist, 3), 4); // unreachable ranks below all reached
    }

    #[test]
    fn lru_evicts_oldest_and_refreshes_on_hit() {
        let tree = |v: u32| {
            Rc::new(SourceTree { dist: vec![v as f32], parents: vec![-1] })
        };
        let mut c = SourceCache::new(2);
        c.insert(1, tree(1));
        c.insert(2, tree(2));
        assert!(c.get(1).is_some()); // refresh 1: now 2 is the LRU
        c.insert(3, tree(3));
        assert!(c.get(2).is_none(), "2 was evicted");
        assert!(c.get(1).is_some() && c.get(3).is_some());
        // Capacity 0 disables caching entirely.
        let mut off = SourceCache::new(0);
        off.insert(1, tree(1));
        assert!(off.get(1).is_none());
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 51.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
    }
}
