//! Landmark oracle: precompute distance tables from `k` high-degree
//! landmark vertices so repeated point-to-point queries become table
//! lookups instead of SSSP runs.
//!
//! Landmarks are chosen by descending out-degree (hubs cover the most
//! pairs on skewed graphs). Each landmark's table `d(L, ·)` is filled by
//! the batched multi-source wave ([`super::wave`]), so the precompute
//! itself is distributed, mirror-aware, and scheme-generic. The tables
//! model the per-locality replicas a production deployment would hold —
//! every locality can answer a covered query locally; the coordinator-held
//! copy here stands in for those replicas.
//!
//! **Symmetric-metric contract:** triangle-inequality bounds and the
//! `t`-is-a-landmark exact case read the tables backwards
//! (`d(t, s) == d(s, t)`), which requires a symmetric metric — a
//! symmetrized graph weighted by
//! [`with_symmetric_random_weights`](crate::graph::generators::with_symmetric_random_weights).
//! The serve coordinator enforces this; [`LandmarkOracle::bounds`]
//! documents the dependency.
//!
//! For any pair `(s, t)` and landmark `L` (symmetric metric):
//!
//! * upper bound: `d(s, t) <= d(L, s) + d(L, t)`;
//! * lower bound: `d(s, t) >= |d(L, s) - d(L, t)|`.
//!
//! The oracle answers *exactly* when `s == t`, when `s` or `t` is a
//! landmark, or when the bounds collapse; everything else is a bound and
//! the query goes to a wave.

use crate::amt::{FlushPolicy, SimConfig, SimReport};
use crate::graph::{Csr, DistGraph, VertexId};

use super::wave;

/// Tolerance under which collapsed bounds count as an exact answer. The
/// serving contract checks answers against the sequential Dijkstra oracle
/// at `1e-3`; collapsing at a 10× tighter threshold keeps bound-derived
/// answers inside that envelope.
const COLLAPSE_EPS: f32 = 1e-4;

/// Precomputed landmark distance tables (and shortest-path trees, so a
/// query *from* a landmark can answer path queries too).
#[derive(Debug)]
pub struct LandmarkOracle {
    /// Landmark vertices, highest degree first.
    pub landmarks: Vec<VertexId>,
    /// `tables[i][v]` = distance from `landmarks[i]` to `v`.
    pub tables: Vec<Vec<f32>>,
    /// `parents[i]` = shortest-path tree rooted at `landmarks[i]`.
    pub parents: Vec<Vec<i64>>,
}

impl LandmarkOracle {
    /// Choose `k` landmarks by descending out-degree and fill their
    /// distance tables with batched multi-source waves of width
    /// `<= batch`. Returns the oracle plus the merged precompute report.
    /// `k == 0` yields an empty oracle (every query is uncovered).
    pub fn build(
        g: &Csr,
        dist_graph: &DistGraph,
        k: usize,
        batch: usize,
        policy: FlushPolicy,
        cfg: &SimConfig,
    ) -> (LandmarkOracle, Option<SimReport>) {
        let landmarks = pick_landmarks(g, k);
        let mut oracle = LandmarkOracle {
            landmarks: landmarks.clone(),
            tables: Vec::with_capacity(landmarks.len()),
            parents: Vec::with_capacity(landmarks.len()),
        };
        let mut report: Option<SimReport> = None;
        for chunk in landmarks.chunks(batch.max(1)) {
            let res = wave::run_wave(g, dist_graph, chunk, policy, cfg.clone());
            oracle.tables.extend(res.dist);
            oracle.parents.extend(res.parents);
            match &mut report {
                None => report = Some(res.report),
                Some(r) => super::merge_reports(r, &res.report),
            }
        }
        (oracle, report)
    }

    /// Index of `v` in the landmark list.
    pub fn landmark_index(&self, v: VertexId) -> Option<usize> {
        self.landmarks.iter().position(|&l| l == v)
    }

    /// Triangle-inequality `(lower, upper)` bounds on `d(s, t)`. Requires
    /// the symmetric-metric contract (module docs). With no landmarks the
    /// bounds are the vacuous `(0, +inf)`.
    pub fn bounds(&self, s: VertexId, t: VertexId) -> (f32, f32) {
        let mut lo = 0.0f32;
        let mut hi = f32::INFINITY;
        for table in &self.tables {
            let (ds, dt) = (table[s as usize], table[t as usize]);
            if ds.is_finite() && dt.is_finite() {
                hi = hi.min(ds + dt);
                lo = lo.max((ds - dt).abs());
            } else if ds.is_finite() != dt.is_finite() {
                // Exactly one endpoint reachable from L: s and t are in
                // different components, distance is infinite.
                return (f32::INFINITY, f32::INFINITY);
            }
        }
        (lo, hi)
    }

    /// Exact distance if this pair is covered: `s == t`, `s` or `t` is a
    /// landmark (symmetric metric for the latter), or the triangle bounds
    /// collapse. `None` means the query must go to a wave.
    pub fn exact_distance(&self, s: VertexId, t: VertexId) -> Option<f32> {
        if s == t {
            return Some(0.0);
        }
        if let Some(i) = self.landmark_index(s) {
            return Some(self.tables[i][t as usize]);
        }
        if let Some(i) = self.landmark_index(t) {
            return Some(self.tables[i][s as usize]);
        }
        let (lo, hi) = self.bounds(s, t);
        if hi.is_infinite() && lo.is_infinite() {
            return Some(f32::INFINITY); // proven disconnected
        }
        (hi - lo <= COLLAPSE_EPS).then_some(hi)
    }

    /// Exact path if `s` is a landmark (its shortest-path tree is stored)
    /// or `s == t`. `None` means uncovered — path queries *to* a landmark
    /// still need a wave (the stored tree is rooted at the landmark and
    /// reversing it assumes edge-level symmetry the serving layer does not
    /// want to rely on for paths).
    pub fn exact_path(&self, s: VertexId, t: VertexId) -> Option<Option<Vec<VertexId>>> {
        if s == t {
            return Some(Some(vec![s]));
        }
        let i = self.landmark_index(s)?;
        Some(crate::algorithms::sssp::recover_path(&self.parents[i], s, t))
    }
}

/// Top-`k` vertices by descending out-degree (ties toward the smaller id,
/// so the choice is deterministic). `k` is clamped to `n`.
pub fn pick_landmarks(g: &Csr, k: usize) -> Vec<VertexId> {
    let n = g.n();
    let mut order: Vec<VertexId> = (0..n as VertexId).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(g.degree(v)), v));
    order.truncate(k.min(n));
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::sssp;
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    fn serve_graph(scale: u32, seed: u64) -> Csr {
        generators::with_symmetric_random_weights(
            &generators::kron(scale, 5, seed),
            1.0,
            10.0,
            seed + 1,
        )
    }

    #[test]
    fn landmarks_are_high_degree_and_deterministic() {
        let g = serve_graph(6, 7);
        let lm = pick_landmarks(&g, 4);
        assert_eq!(lm.len(), 4);
        assert_eq!(lm, pick_landmarks(&g, 4));
        let min_picked = lm.iter().map(|&v| g.degree(v)).min().unwrap();
        let max_any = (0..g.n() as VertexId)
            .filter(|v| !lm.contains(v))
            .map(|v| g.degree(v))
            .max()
            .unwrap();
        assert!(min_picked >= max_any, "picked {min_picked} < unpicked {max_any}");
        // k is clamped.
        assert_eq!(pick_landmarks(&g, g.n() + 10).len(), g.n());
        assert!(pick_landmarks(&g, 0).is_empty());
    }

    #[test]
    fn tables_match_dijkstra_and_bounds_sandwich_truth() {
        let g = serve_graph(6, 15);
        let d = DistGraph::block(&g, 4);
        let (oracle, report) =
            LandmarkOracle::build(&g, &d, 4, 2, FlushPolicy::Adaptive, &det());
        assert!(report.is_some());
        for (i, &l) in oracle.landmarks.iter().enumerate() {
            let want = sssp::dijkstra(&g, l);
            for (v, (&got, &exp)) in oracle.tables[i].iter().zip(&want).enumerate() {
                let ok = (got.is_infinite() && exp.is_infinite()) || (got - exp).abs() < 1e-3;
                assert!(ok, "landmark {l} v={v}: {got} vs {exp}");
            }
        }
        // Bounds sandwich the true distance for random pairs.
        let mut rng = generators::SplitMix64::new(99);
        for _ in 0..50 {
            let s = rng.below(g.n() as u64) as VertexId;
            let t = rng.below(g.n() as u64) as VertexId;
            let truth = sssp::dijkstra(&g, s)[t as usize];
            let (lo, hi) = oracle.bounds(s, t);
            if truth.is_finite() {
                assert!(lo <= truth + 1e-2, "({s},{t}): lower {lo} > truth {truth}");
                assert!(hi >= truth - 1e-2, "({s},{t}): upper {hi} < truth {truth}");
            }
            if let Some(exact) = oracle.exact_distance(s, t) {
                let ok = (exact.is_infinite() && truth.is_infinite())
                    || (exact - truth).abs() < 1e-3;
                assert!(ok, "({s},{t}): exact {exact} vs truth {truth}");
            }
        }
    }

    #[test]
    fn landmark_queries_are_covered_under_vertex_cut() {
        let g = serve_graph(6, 23);
        let d = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 4));
        let (oracle, _) = LandmarkOracle::build(&g, &d, 3, 8, FlushPolicy::Adaptive, &det());
        let l = oracle.landmarks[0];
        assert!(oracle.exact_distance(l, 5).is_some());
        assert!(oracle.exact_distance(5, l).is_some(), "t-landmark uses symmetry");
        assert!(oracle.exact_path(l, 5).is_some());
        assert!(oracle.exact_path(5, l).is_none(), "paths to a landmark are uncovered");
        assert_eq!(oracle.exact_distance(9, 9), Some(0.0));
    }

    #[test]
    fn empty_oracle_covers_only_trivial_pairs() {
        let g = serve_graph(6, 41);
        let d = DistGraph::block(&g, 2);
        let (oracle, report) =
            LandmarkOracle::build(&g, &d, 0, 4, FlushPolicy::Adaptive, &det());
        assert!(report.is_none());
        assert_eq!(oracle.bounds(1, 2), (0.0, f32::INFINITY));
        assert_eq!(oracle.exact_distance(3, 3), Some(0.0));
        assert_eq!(oracle.exact_distance(1, 2), None);
    }
}
