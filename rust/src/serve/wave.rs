//! Batched multi-source SSSP: one engine run relaxes a whole batch of
//! sources at once. States and messages are fixed-width vectors of
//! [`DistParent`] — one lane per source — folded by elementwise
//! distance-min, which stays associative, commutative, and idempotent, so
//! the program is an ordinary monotone [`Mode::Converge`] program and runs
//! on the generic mirror-aware async engine under every partition scheme
//! (vertex cuts included; the serve layer never calls
//! `engine::require_mirror_free`).
//!
//! This is the query-serving amortization lever: `B` concurrent uncovered
//! queries share one wavefront (one flood of width-`B` messages through
//! the aggregator) instead of `B` sequential SSSP runs.

use crate::algorithms::sssp::{self, DistParent};
use crate::amt::{FlushPolicy, SimConfig, SimReport};
use crate::engine::{self, Mode, ProgramInfo, VertexProgram};
use crate::graph::{Csr, DistGraph, VertexId};

/// A width-`B` vector of `(distance, parent)` lanes. The empty vector is
/// the fold identity (`Default` backs the aggregator's retired-slot
/// storage and is never read as a real value).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WaveMsg(pub Vec<DistParent>);

impl WaveMsg {
    fn fold(&mut self, new: &WaveMsg) -> bool {
        if new.0.is_empty() {
            return false;
        }
        if self.0.is_empty() {
            self.0 = new.0.clone();
            return true;
        }
        debug_assert_eq!(self.0.len(), new.0.len(), "wave width mismatch");
        let mut changed = false;
        for (acc, n) in self.0.iter_mut().zip(&new.0) {
            if n.beats(acc) {
                *acc = *n;
                changed = true;
            }
        }
        changed
    }
}

/// Multi-source label-correcting SSSP over a batch of sources.
#[derive(Debug, Clone)]
pub struct MultiSourceSssp {
    /// The batch; lane `i` computes the shortest-path tree from
    /// `sources[i]`.
    pub sources: Vec<VertexId>,
}

impl VertexProgram for MultiSourceSssp {
    type State = WaveMsg;
    type Msg = WaveMsg;

    fn info(&self) -> ProgramInfo {
        ProgramInfo {
            name: "sssp-wave",
            mode: Mode::Converge,
            needs_weights: true,
            // A vector of distances is not a single path metric, so the
            // ordered bucket schedule does not apply; the async wavefront
            // orders on the minimum lane instead.
            ordered: false,
            item_bytes: 4 + 12 * self.sources.len(),
        }
    }

    fn init(&self, _v: VertexId, _out_degree: u32) -> WaveMsg {
        WaveMsg(vec![DistParent::default(); self.sources.len()])
    }

    fn seed(&self, v: VertexId) -> Option<WaveMsg> {
        if !self.sources.contains(&v) {
            return None;
        }
        let mut lanes = vec![DistParent::default(); self.sources.len()];
        for (i, &s) in self.sources.iter().enumerate() {
            if s == v {
                lanes[i] = DistParent { dist: 0.0, parent: v as i64 };
            }
        }
        Some(WaveMsg(lanes))
    }

    fn combine(acc: &mut WaveMsg, new: WaveMsg) {
        acc.fold(&new);
    }

    fn beats(&self, msg: &WaveMsg, state: &WaveMsg) -> bool {
        if msg.0.is_empty() {
            return false;
        }
        if state.0.is_empty() {
            return true;
        }
        msg.0.iter().zip(&state.0).any(|(m, s)| m.beats(s))
    }

    fn apply(&self, state: &mut WaveMsg, msg: WaveMsg) -> bool {
        state.fold(&msg)
    }

    fn signal(&self, state: &WaveMsg) -> WaveMsg {
        state.clone()
    }

    fn along_edge(&self, u: VertexId, sig: &WaveMsg, w: f32) -> WaveMsg {
        WaveMsg(
            sig.0
                .iter()
                .map(|dp| {
                    if dp.dist.is_finite() {
                        DistParent { dist: dp.dist + w, parent: u as i64 }
                    } else {
                        DistParent::default()
                    }
                })
                .collect(),
        )
    }

    fn priority(&self, msg: &WaveMsg) -> f32 {
        // The nearest lane drives the wavefront order.
        msg.0.iter().map(|dp| dp.dist).fold(f32::INFINITY, f32::min).min(1e30)
    }
}

/// One multi-source wave: per-lane shortest-path trees.
#[derive(Debug)]
pub struct WaveResult {
    /// `dist[i][v]` = distance from `sources[i]` to `v`.
    pub dist: Vec<Vec<f32>>,
    /// `parents[i]` = shortest-path tree of `sources[i]` (walk with
    /// [`sssp::recover_path`]).
    pub parents: Vec<Vec<i64>>,
    /// Runtime report of the wave.
    pub report: SimReport,
}

/// Run one batched multi-source SSSP wave on the generic async engine.
pub fn run_wave(
    g: &Csr,
    dist_graph: &DistGraph,
    sources: &[VertexId],
    policy: FlushPolicy,
    cfg: SimConfig,
) -> WaveResult {
    assert!(!sources.is_empty(), "a wave needs at least one source");
    sssp::check_graph_matches(g, dist_graph);
    let prog = MultiSourceSssp { sources: sources.to_vec() };
    let run = engine::run_async(prog, dist_graph, policy, cfg);
    let b = sources.len();
    let n = run.states.len();
    let mut dist = vec![vec![f32::INFINITY; n]; b];
    let mut parents = vec![vec![-1i64; n]; b];
    for (v, lanes) in run.states.iter().enumerate() {
        debug_assert_eq!(lanes.0.len(), b);
        for (i, dp) in lanes.0.iter().enumerate() {
            dist[i][v] = dp.dist;
            parents[i][v] = dp.parent;
        }
    }
    WaveResult { dist, parents, report: run.report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::NetConfig;
    use crate::graph::{generators, PartitionKind};

    fn det() -> SimConfig {
        SimConfig::deterministic(NetConfig::default())
    }

    fn close(a: &[f32], b: &[f32]) -> bool {
        a.iter()
            .zip(b)
            .all(|(x, y)| (x.is_infinite() && y.is_infinite()) || (x - y).abs() < 1e-3)
    }

    #[test]
    fn wave_lanes_match_per_source_dijkstra() {
        let g = generators::with_symmetric_random_weights(
            &generators::urand(6, 4, 5),
            1.0,
            10.0,
            6,
        );
        let sources = [0u32, 7, 13, 21];
        let d = DistGraph::block(&g, 4);
        let res = run_wave(&g, &d, &sources, FlushPolicy::Adaptive, det());
        for (i, &s) in sources.iter().enumerate() {
            let want = sssp::dijkstra(&g, s);
            assert!(close(&res.dist[i], &want), "lane {i} (source {s})");
        }
    }

    #[test]
    fn wave_works_under_every_partition_scheme() {
        let g = generators::with_symmetric_random_weights(
            &generators::kron(6, 5, 33),
            1.0,
            10.0,
            34,
        );
        let sources = [1u32, 2, 3];
        let wants: Vec<Vec<f32>> = sources.iter().map(|&s| sssp::dijkstra(&g, s)).collect();
        for kind in PartitionKind::all() {
            for p in [2u32, 4, 8] {
                let d = DistGraph::build_with(&g, kind.build(&g, p));
                let res = run_wave(&g, &d, &sources, FlushPolicy::Adaptive, det());
                for (i, want) in wants.iter().enumerate() {
                    assert!(close(&res.dist[i], want), "{kind:?} p={p} lane {i}");
                }
            }
        }
    }

    #[test]
    fn wave_paths_are_edge_valid() {
        let g = generators::with_symmetric_random_weights(
            &generators::urand(6, 4, 91),
            1.0,
            10.0,
            92,
        );
        let sources = [3u32, 40];
        let d = DistGraph::build_with(&g, PartitionKind::Hash.build(&g, 4));
        let res = run_wave(&g, &d, &sources, FlushPolicy::Items(16), det());
        for (i, &s) in sources.iter().enumerate() {
            for v in 0..g.n() as VertexId {
                if !res.dist[i][v as usize].is_finite() {
                    continue;
                }
                let path = sssp::recover_path(&res.parents[i], s, v)
                    .unwrap_or_else(|| panic!("lane {i}: no path to {v}"));
                let w = sssp::path_weight(&g, &path).expect("edge-valid");
                assert!((w - res.dist[i][v as usize]).abs() < 1e-3, "lane {i} v={v}");
            }
        }
    }
}
