//! Mini property-testing harness (proptest is unavailable offline).
//!
//! [`forall`] runs a property over generated cases; on failure it *shrinks*
//! by retrying the property on the generator's simpler outputs (halved
//! sizes/seeds) and reports the smallest failing case it found. Generators
//! are plain functions from a PRNG + size budget to a value, so graph- and
//! partition-specific generators compose naturally.

use crate::graph::generators::SplitMix64;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: u32,
    /// Base seed (every case derives its own).
    pub seed: u64,
    /// Maximum size budget handed to generators.
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE, max_size: 64 }
    }
}

impl PropConfig {
    /// Build a config honoring the property-suite environment knobs:
    /// `NWGRAPH_PROP_SEED` overrides `seed` (the CI seed matrix) and
    /// `NWGRAPH_PROP_CASES` overrides `cases` (shrink case counts for
    /// fast local runs, e.g. `NWGRAPH_PROP_CASES=4 cargo test`).
    pub fn from_env(cases: u32, seed: u64, max_size: usize) -> PropConfig {
        let env_u64 = |key: &str| std::env::var(key).ok().and_then(|s| s.parse::<u64>().ok());
        PropConfig {
            cases: env_u64("NWGRAPH_PROP_CASES").map(|c| c.max(1) as u32).unwrap_or(cases),
            seed: env_u64("NWGRAPH_PROP_SEED").unwrap_or(seed),
            max_size,
        }
    }
}

/// A generated case with the inputs that produced it (for shrinking).
pub struct Case<T> {
    /// The generated value.
    pub value: T,
    seed: u64,
    size: usize,
}

/// Run `prop` over `cfg.cases` values from `gen`; panics with the smallest
/// failing case's diagnostics on failure.
///
/// `gen(rng, size)` must be deterministic in `(seed, size)`.
pub fn forall<T, G, P>(cfg: &PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut SplitMix64, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case_no in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case_no as u64).wrapping_mul(0x9E3779B97F4A7C15);
        // Ramp the size budget up across cases: early cases are small.
        let size = 1 + (cfg.max_size - 1) * case_no as usize / cfg.cases.max(1) as usize;
        let mut rng = SplitMix64::new(seed);
        let value = gen(&mut rng, size);
        if let Err(msg) = prop(&value) {
            // Shrink: retry with smaller sizes on the same seed, keeping
            // the smallest size that still fails.
            let mut worst = Case { value, seed, size };
            let mut err = msg;
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = SplitMix64::new(seed);
                let v = gen(&mut rng, s);
                match prop(&v) {
                    Err(m) => {
                        worst = Case { value: v, seed, size: s };
                        err = m;
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property failed (case {case_no}, seed {:#x}, size {}):\n  {}\n  value: {:?}",
                worst.seed, worst.size, err, worst.value
            );
        }
    }
}

/// Generator helpers for graph properties.
pub mod gen {
    use crate::graph::generators::SplitMix64;
    use crate::graph::{Csr, EdgeList, VertexId};

    /// Random vertex count in `[1, size]`.
    pub fn vertex_count(rng: &mut SplitMix64, size: usize) -> usize {
        1 + rng.below(size as u64) as usize
    }

    /// Random sparse directed graph with up to `3 * n` edges.
    pub fn digraph(rng: &mut SplitMix64, size: usize) -> Csr {
        let n = vertex_count(rng, size);
        let m = rng.below(3 * n as u64 + 1) as usize;
        let mut el = EdgeList::new(n);
        for _ in 0..m {
            let u = rng.below(n as u64) as VertexId;
            let v = rng.below(n as u64) as VertexId;
            if u != v {
                el.push(u, v);
            }
        }
        el.dedup();
        Csr::from_edge_list(&el)
    }

    /// Random symmetric graph.
    pub fn ugraph(rng: &mut SplitMix64, size: usize) -> Csr {
        let n = vertex_count(rng, size);
        let m = rng.below(3 * n as u64 + 1) as usize;
        let mut el = EdgeList::new(n);
        for _ in 0..m {
            let u = rng.below(n as u64) as VertexId;
            let v = rng.below(n as u64) as VertexId;
            el.push(u, v);
        }
        el.symmetrize();
        Csr::from_edge_list(&el)
    }

    /// Random locality count in `[1, 8]`.
    pub fn locality_count(rng: &mut SplitMix64, _size: usize) -> u32 {
        1 + rng.below(8) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_on_true_property() {
        forall(
            &PropConfig { cases: 32, ..Default::default() },
            |rng, size| rng.below(size as u64 + 1),
            |&v| if v <= 64 { Ok(()) } else { Err(format!("{v} > 64")) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(
            &PropConfig { cases: 16, ..Default::default() },
            |_, size| size,
            |&s| if s < 8 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn shrinking_finds_smaller_case() {
        let caught = std::panic::catch_unwind(|| {
            forall(
                &PropConfig { cases: 8, seed: 7, max_size: 64 },
                |_, size| size,
                |&s| if s < 2 { Ok(()) } else { Err("fails for >= 2".into()) },
            );
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // The shrink loop must have reduced the size to the minimal failing
        // value (2 or 3 depending on halving path), well below max.
        assert!(msg.contains("size 2") || msg.contains("size 3"), "{msg}");
    }

    #[test]
    fn from_env_defaults_without_env() {
        // The env vars are unset in unit-test runs that don't opt in.
        if std::env::var("NWGRAPH_PROP_SEED").is_err()
            && std::env::var("NWGRAPH_PROP_CASES").is_err()
        {
            let c = PropConfig::from_env(32, 0xAB, 48);
            assert_eq!(c.cases, 32);
            assert_eq!(c.seed, 0xAB);
            assert_eq!(c.max_size, 48);
        }
    }

    #[test]
    fn graph_generators_produce_valid_graphs() {
        let mut rng = SplitMix64::new(1);
        for _ in 0..20 {
            let g = gen::digraph(&mut rng, 32);
            assert!(g.n() >= 1);
            for u in 0..g.n() as u32 {
                for &v in g.neighbors(u) {
                    assert!((v as usize) < g.n());
                    assert_ne!(u, v);
                }
            }
            let ug = gen::ugraph(&mut rng, 32);
            for u in 0..ug.n() as u32 {
                for &v in ug.neighbors(u) {
                    assert!(ug.has_edge(v, u));
                }
            }
        }
    }
}
