//! Property and accounting tests for the `amt::aggregate` subsystem
//! itself: conservation under random flush policies and message schedules,
//! quiescence with pending buffers, SimReport accounting against real wire
//! traffic, and the ablation acceptance criterion on a skewed graph.

use nwgraph_hpx::algorithms::pagerank::{self, PrParams};
use nwgraph_hpx::amt::aggregate::{AggStats, Aggregator, Batch, SlotSpace};
use nwgraph_hpx::amt::sim::Message;
use nwgraph_hpx::amt::{
    Actor, Ctx, FlushPolicy, LocalityId, NetConfig, SimConfig, SimRuntime,
};
use nwgraph_hpx::graph::{generators, DistGraph};
use nwgraph_hpx::testing::{forall, PropConfig};

fn cfg(cases: u32) -> PropConfig {
    // NWGRAPH_PROP_SEED / NWGRAPH_PROP_CASES override seed and case count.
    PropConfig::from_env(cases, 0xC0FFEE, 64)
}

fn gen_policy(rng: &mut generators::SplitMix64) -> FlushPolicy {
    match rng.below(7) {
        0 => FlushPolicy::Unbatched,
        1 => FlushPolicy::Items(1 + rng.below(32) as usize),
        2 => FlushPolicy::Bytes(8 + rng.below(512) as usize),
        3 => FlushPolicy::Adaptive,
        4 => FlushPolicy::TimeWindow(rng.below(50)),
        5 => FlushPolicy::LatencyAdaptive,
        _ => FlushPolicy::Manual,
    }
}

fn add(acc: &mut u64, v: u64) {
    *acc += v;
}

fn min_u64(acc: &mut u64, v: u64) {
    *acc = (*acc).min(v);
}

/// A random push/drain schedule against one aggregator.
#[derive(Debug, Clone)]
struct Schedule {
    /// Destination slot counts (`n_dst` localities; a slot is the
    /// destination's dense master index).
    sizes: Vec<usize>,
    here: u32,
    policy: FlushPolicy,
    /// `(op, dst, slot, value)`; `op == 0` pushes, `op == 1` drains the
    /// destination mid-stream.
    ops: Vec<(u8, u32, u32, u64)>,
}

fn gen_schedule(rng: &mut generators::SplitMix64, size: usize) -> Schedule {
    let p = 2 + rng.below(7) as usize; // 2..=8 localities
    let sizes: Vec<usize> = (0..p).map(|_| 1 + rng.below(size as u64 + 1) as usize).collect();
    let here = rng.below(p as u64) as u32;
    let policy = gen_policy(rng);
    let n_ops = rng.below(8 * size as u64 + 1) as usize;
    let mut ops = Vec::with_capacity(n_ops);
    for _ in 0..n_ops {
        let op = (rng.below(10) == 0) as u8; // ~10% mid-stream drains
        let mut dst = rng.below(p as u64) as u32;
        if dst == here {
            dst = (dst + 1) % p as u32;
        }
        let off = rng.below(sizes[dst as usize] as u64) as u32;
        let val = 1 + rng.below(100);
        ops.push((op, dst, off, val));
    }
    Schedule { sizes, here, policy, ops }
}

/// Flatten `(dst, slot)` into one global accounting index.
fn flat(sizes: &[usize], dst: u32, slot: u32) -> usize {
    sizes[..dst as usize].iter().sum::<usize>() + slot as usize
}

#[test]
fn prop_no_item_dropped_or_duplicated_sum_fold() {
    // Conservation of folded sums: across random policies and schedules,
    // the per-vertex sum over everything the aggregator emits equals the
    // per-vertex sum of everything pushed in — nothing dropped, nothing
    // duplicated.
    forall(&cfg(64), gen_schedule, |s| {
        let total: usize = s.sizes.iter().sum();
        let mut agg = Aggregator::new(
            &s.sizes,
            s.here,
            SlotSpace::Master,
            s.policy,
            &NetConfig::default(),
            8,
            add,
        );
        let mut want = vec![0u64; total];
        let mut got = vec![0u64; total];
        let fold_in = |acc: &mut Vec<u64>, dst: u32, b: &Batch<u64>| {
            for &(slot, x) in &b.items {
                acc[flat(&s.sizes, dst, slot)] += x;
            }
        };
        for (i, &(op, dst, off, val)) in s.ops.iter().enumerate() {
            if op == 0 {
                want[flat(&s.sizes, dst, off)] += val;
                if let Some(b) = agg.accumulate(dst, off, val, i as f64) {
                    fold_in(&mut got, dst, &b);
                }
            } else if let Some(b) = agg.drain_one(dst) {
                fold_in(&mut got, dst, &b);
            }
        }
        for (dst, b) in agg.drain() {
            if b.is_empty() {
                return Err(format!("drain returned empty batch for {dst}"));
            }
            fold_in(&mut got, dst, &b);
        }
        if agg.pending() != 0 {
            return Err(format!("{} items still pending after drain", agg.pending()));
        }
        if got != want {
            return Err("folded sums differ from pushed sums".into());
        }
        let st: AggStats = *agg.stats();
        if st.items != st.folded + st.sent_items {
            return Err(format!("stats do not balance: {st:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_no_item_dropped_min_fold() {
    // Conservation of folded mins: the emitted min per vertex equals the
    // true min of everything pushed at it (duplicates collapse, the
    // winner survives).
    forall(&cfg(64), gen_schedule, |s| {
        let total: usize = s.sizes.iter().sum();
        let mut agg = Aggregator::new(
            &s.sizes,
            s.here,
            SlotSpace::Master,
            s.policy,
            &NetConfig::default(),
            8,
            min_u64,
        );
        let mut want = vec![u64::MAX; total];
        let mut got = vec![u64::MAX; total];
        for (t, &(op, dst, off, val)) in s.ops.iter().enumerate() {
            if op == 0 {
                let i = flat(&s.sizes, dst, off);
                want[i] = want[i].min(val);
                if let Some(b) = agg.accumulate(dst, off, val, t as f64) {
                    for (slot, x) in b.items {
                        let i = flat(&s.sizes, dst, slot);
                        got[i] = got[i].min(x);
                    }
                }
            } else if let Some(b) = agg.drain_one(dst) {
                for (slot, x) in b.items {
                    let i = flat(&s.sizes, dst, slot);
                    got[i] = got[i].min(x);
                }
            }
        }
        for (dst, b) in agg.drain() {
            for (slot, x) in b.items {
                let i = flat(&s.sizes, dst, slot);
                got[i] = got[i].min(x);
            }
        }
        if got != want {
            return Err("folded mins differ from pushed mins".into());
        }
        Ok(())
    });
}

/// Actor for the quiescence test: locality 0 sprays `n` values at the
/// other localities through a Manual-policy aggregator, flushing *nothing*
/// until the end-of-handler drain — so at the moment the policy is
/// consulted for the last time, buffers are still non-empty.
struct Sprayer {
    agg: Aggregator<u64>,
    to_send: u64,
    received: u64,
}

#[derive(Debug, Clone)]
struct Payload(Batch<u64>);

impl Message for Payload {
    fn wire_bytes(&self) -> usize {
        self.0.wire_bytes()
    }

    fn item_count(&self) -> usize {
        self.0.len()
    }
}

impl Actor for Sprayer {
    type Msg = Payload;

    fn on_start(&mut self, ctx: &mut Ctx<Payload>) {
        if ctx.locality() != 0 {
            return;
        }
        let p = ctx.n_localities();
        for i in 0..self.to_send {
            let dst = 1 + (i % (p as u64 - 1)) as LocalityId;
            // Slots collide on purpose: the fold sums them.
            if let Some(b) = self.agg.accumulate(dst, (i % 4) as u32, 1, ctx.now()) {
                ctx.send(dst, Payload(b));
            }
        }
        assert!(self.agg.pending() > 0, "Manual policy must leave items buffered");
        for (dst, b) in self.agg.drain() {
            ctx.send(dst, Payload(b));
        }
    }

    fn on_message(&mut self, _ctx: &mut Ctx<Payload>, _from: LocalityId, msg: Payload) {
        self.received += msg.0.items.iter().map(|&(_, x)| x).sum::<u64>();
    }
}

#[test]
fn quiescence_fires_after_draining_pending_buffers() {
    // Termination is network quiescence; buffers that were still pending
    // when the send loop ended are shipped by the drain, delivered, and
    // the run still terminates with nothing lost.
    let p = 4u32;
    let counts = [4usize, 4, 4, 4];
    let net = NetConfig::default();
    let actors: Vec<Sprayer> = (0..p)
        .map(|l| Sprayer {
            agg: Aggregator::new(
                &counts,
                l,
                SlotSpace::Master,
                FlushPolicy::Manual,
                &net,
                8,
                add,
            ),
            to_send: 300,
            received: 0,
        })
        .collect();
    let (actors, report) =
        SimRuntime::new(SimConfig::deterministic(net.clone())).run(actors);
    let received: u64 = actors.iter().map(|a| a.received).sum();
    assert_eq!(received, 300, "every sprayed unit must arrive");
    // Manual policy: exactly one envelope per destination.
    assert_eq!(report.net.envelopes, 3);
    assert_eq!(report.barriers, 0);
}

#[test]
fn simreport_counters_equal_actual_sends() {
    // Satellite acceptance: the envelope/item counters merged into
    // SimReport equal the wire traffic the engine actually recorded, for
    // every policy on the same workload.
    let g = generators::urand_directed(7, 6, 3);
    let dist = DistGraph::block(&g, 4);
    let params = PrParams { alpha: 0.85, iterations: 3 };
    for policy in [
        FlushPolicy::Unbatched,
        FlushPolicy::Items(32),
        FlushPolicy::Bytes(512),
        FlushPolicy::Adaptive,
        FlushPolicy::LatencyAdaptive,
        FlushPolicy::TimeWindow(5),
        FlushPolicy::Manual,
    ] {
        let res = pagerank::run_async(
            &dist,
            params,
            policy,
            SimConfig::deterministic(NetConfig::default()),
        );
        assert_eq!(res.report.agg.envelopes, res.report.net.envelopes, "{policy:?}");
        assert_eq!(res.report.agg.sent_items, res.report.net.messages, "{policy:?}");
        assert_eq!(
            res.report.agg.envelopes,
            res.report.agg.policy_flushes + res.report.agg.drain_flushes,
            "{policy:?}"
        );
    }
}

#[test]
fn manual_drain_reproduces_optimized_variant_envelopes() {
    // Satellite acceptance: maximal batching (the old Optimized variant
    // with `flush_block == n_local`) produces exactly the BSP wire
    // schedule — one envelope per non-empty destination pair per
    // iteration.
    let g = generators::urand_directed(7, 8, 11);
    let dist = DistGraph::block(&g, 8);
    let params = PrParams { alpha: 0.85, iterations: 6 };
    let manual = pagerank::run_async(
        &dist,
        params,
        FlushPolicy::Manual,
        SimConfig::deterministic(NetConfig::default()),
    );
    let bsp = pagerank::run_bsp(&dist, params, SimConfig::deterministic(NetConfig::default()));
    assert_eq!(manual.report.net.envelopes, bsp.report.net.envelopes);
    assert_eq!(manual.report.net.messages, bsp.report.net.messages);
}

/// Replay one schedule through a policy, feeding a synthetic constant-wire
/// delivery ack for every traced envelope (so the latency tuner actually
/// moves); returns the total envelope count.
fn replay_envelopes(s: &Schedule, policy: FlushPolicy) -> u64 {
    let mut agg: Aggregator<u64> = Aggregator::new(
        &s.sizes,
        s.here,
        SlotSpace::Master,
        policy,
        &NetConfig::default(),
        8,
        add,
    );
    let mut counted = 0u64;
    let mut ship = |agg: &mut Aggregator<u64>, b: Batch<u64>, t: f64, counted: &mut u64| {
        *counted += 1;
        if let Some(tok) = b.token() {
            // Synthetic delivery: constant wire latency plus a per-item
            // marshalling share — enough signal for the hill climber.
            agg.observe_ack(tok, t, t + 2.0 + 0.05 * b.len() as f64);
        }
    };
    for (i, &(op, dst, off, val)) in s.ops.iter().enumerate() {
        let t = i as f64;
        if op == 0 {
            if let Some(b) = agg.accumulate(dst, off, val, t) {
                ship(&mut agg, b, t, &mut counted);
            }
        } else if let Some(b) = agg.drain_one(dst) {
            ship(&mut agg, b, t, &mut counted);
        }
        for (_, b) in agg.poll(t) {
            ship(&mut agg, b, t, &mut counted);
        }
    }
    let t_end = s.ops.len() as f64;
    for (_, b) in agg.drain() {
        ship(&mut agg, b, t_end, &mut counted);
    }
    assert_eq!(counted, agg.stats().envelopes, "every emitted batch was counted");
    assert_eq!(agg.pending(), 0);
    counted
}

#[test]
fn prop_latency_adaptive_envelopes_bounded_by_unbatched_and_manual() {
    // Satellite acceptance: on the same push/drain trace, the self-tuning
    // policy can never emit more envelopes than Unbatched (one per item)
    // and never fewer than Manual (drains only) — the tuner moves the
    // threshold, but only within [break-even, 64x break-even], so the
    // bound holds whatever latencies it observes.
    forall(&cfg(48), gen_schedule, |s| {
        let unbatched = replay_envelopes(s, FlushPolicy::Unbatched);
        let latency = replay_envelopes(s, FlushPolicy::LatencyAdaptive);
        let manual = replay_envelopes(s, FlushPolicy::Manual);
        if latency > unbatched {
            return Err(format!("latency {latency} > unbatched {unbatched}"));
        }
        if latency < manual {
            return Err(format!("latency {latency} < manual {manual}"));
        }
        Ok(())
    });
}

#[test]
fn prop_time_window_zero_equals_unbatched() {
    // Satellite acceptance: `time:0` is exactly the unbatched policy —
    // same envelope stream, same accounting, no combiner state.
    forall(&cfg(32), gen_schedule, |s| {
        let run = |policy: FlushPolicy| {
            let mut agg: Aggregator<u64> = Aggregator::new(
                &s.sizes,
                s.here,
                SlotSpace::Master,
                policy,
                &NetConfig::default(),
                8,
                add,
            );
            let mut emitted: Vec<(u32, Vec<(u32, u64)>)> = Vec::new();
            for (i, &(op, dst, off, val)) in s.ops.iter().enumerate() {
                if op == 0 {
                    if let Some(b) = agg.accumulate(dst, off, val, i as f64) {
                        emitted.push((dst, b.into_items()));
                    }
                } else if let Some(b) = agg.drain_one(dst) {
                    emitted.push((dst, b.into_items()));
                }
            }
            for (dst, b) in agg.drain() {
                emitted.push((dst, b.into_items()));
            }
            (emitted, *agg.stats())
        };
        let (tw, tw_stats) = run(FlushPolicy::TimeWindow(0));
        let (ub, ub_stats) = run(FlushPolicy::Unbatched);
        if tw != ub {
            return Err("time:0 emitted a different envelope stream".into());
        }
        if tw_stats != ub_stats {
            return Err(format!("stats diverge: {tw_stats:?} vs {ub_stats:?}"));
        }
        Ok(())
    });
}

#[test]
fn ablation_acceptance_rmat_8_localities() {
    // Acceptance criterion: on an RMAT graph at 8 localities, aggregated
    // async PageRank issues >= 10x fewer envelopes than the unbatched
    // policy, with ranks matching sequential within 1e-4 Linf.
    let g = generators::kron(10, 8, 5);
    let dist = DistGraph::block(&g, 8);
    let params = PrParams { alpha: 0.85, iterations: 10 };
    let want = pagerank::sequential::pagerank(&g, params);
    let sim = || SimConfig::deterministic(NetConfig::default());
    let naive = pagerank::run_async(&dist, params, FlushPolicy::Unbatched, sim());
    for policy in [FlushPolicy::Adaptive, FlushPolicy::Manual] {
        let agg = pagerank::run_async(&dist, params, policy, sim());
        assert!(
            agg.report.net.envelopes * 10 <= naive.report.net.envelopes,
            "{policy:?}: {} vs naive {}",
            agg.report.net.envelopes,
            naive.report.net.envelopes
        );
        assert!(pagerank::max_abs_diff(&agg.ranks, &want) < 1e-4, "{policy:?}");
    }
    assert!(pagerank::max_abs_diff(&naive.ranks, &want) < 1e-4);
}
