//! Property-based integration tests over the distributed algorithms.
//!
//! Uses the crate's mini property harness (`testing::forall`) — random
//! graphs × random partitions, asserting the distributed engines agree
//! with the sequential oracles and the runtime invariants hold.

use nwgraph_hpx::algorithms::{bfs, cc, pagerank, pagerank::PrParams, sssp, triangle};
use nwgraph_hpx::amt::{FlushPolicy, NetConfig, SimConfig};
use nwgraph_hpx::graph::{generators, Csr, DistGraph, Partition1D};
use nwgraph_hpx::testing::{forall, gen, PropConfig};

fn det() -> SimConfig {
    SimConfig::deterministic(NetConfig::default())
}

fn cfg(cases: u32) -> PropConfig {
    // NWGRAPH_PROP_SEED / NWGRAPH_PROP_CASES override seed and case count.
    PropConfig::from_env(cases, 0xDEADBEEF, 48)
}

/// Draw a flush policy uniformly from the interesting corners of the
/// policy space (used by the cross-model agreement properties).
fn gen_policy(rng: &mut generators::SplitMix64) -> FlushPolicy {
    match rng.below(5) {
        0 => FlushPolicy::Unbatched,
        1 => FlushPolicy::Items(1 + rng.below(64) as usize),
        2 => FlushPolicy::Bytes(8 + rng.below(1024) as usize),
        3 => FlushPolicy::Adaptive,
        _ => FlushPolicy::Manual,
    }
}

#[test]
fn prop_partition_covers_every_vertex_exactly_once() {
    forall(
        &cfg(64),
        |rng, size| (gen::vertex_count(rng, size * 4), gen::locality_count(rng, size)),
        |&(n, p)| {
            let part = Partition1D::block(n, p);
            let mut seen = vec![0u32; n];
            for l in 0..p {
                for v in part.range_of(l) {
                    seen[v] += 1;
                    if part.owner(v as u32) != l {
                        return Err(format!("owner({v}) != {l}"));
                    }
                }
            }
            if seen.iter().any(|&c| c != 1) {
                return Err("partition not a cover".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_async_bfs_tree_valid_and_reaches_oracle_set() {
    forall(
        &cfg(40),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let p = gen::locality_count(rng, size);
            let root = rng.below(g.n() as u64) as u32;
            (g, p, root)
        },
        |(g, p, root)| {
            let dist = DistGraph::block(g, *p);
            let res = bfs::run_async(&dist, *root, det());
            bfs::validate_parents(g, *root, &res.parents)?;
            let want = bfs::sequential::bfs(g, *root);
            for v in 0..g.n() {
                if (res.parents[v] >= 0) != (want[v] >= 0) {
                    return Err(format!("reachability mismatch at {v}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bsp_bfs_levels_are_minimal() {
    forall(
        &cfg(30),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let p = gen::locality_count(rng, size);
            let root = rng.below(g.n() as u64) as u32;
            (g, p, root)
        },
        |(g, p, root)| {
            let dist = DistGraph::block(g, *p);
            let res = bfs::run_bsp(&dist, *root, det());
            bfs::validate_parents(g, *root, &res.parents)?;
            let lv = bfs::tree_levels(*root, &res.parents);
            let d = bfs::sequential::distances(g, *root);
            if lv != d {
                return Err("BSP levels not minimal".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pagerank_engines_agree_with_oracle() {
    let params = PrParams { alpha: 0.85, iterations: 10 };
    forall(
        &cfg(25),
        |rng, size| {
            let g = gen::digraph(rng, size);
            let p = gen::locality_count(rng, size);
            (g, p)
        },
        |(g, p)| {
            let dist = DistGraph::block(g, *p);
            let want = pagerank::sequential::pagerank(g, params);
            for (name, res) in [
                ("bsp", pagerank::run_bsp(&dist, params, det())),
                (
                    "naive",
                    pagerank::run_async(&dist, params, FlushPolicy::Unbatched, det()),
                ),
                (
                    "opt",
                    pagerank::run_async(&dist, params, FlushPolicy::Items(7), det()),
                ),
            ] {
                let diff = pagerank::max_abs_diff(&res.ranks, &want);
                if diff > 1e-5 {
                    return Err(format!("{name}: diff {diff}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pagerank_mass_conserved_without_dangling() {
    // When every vertex has out-degree >= 1, ranks sum to ~1.
    let params = PrParams { alpha: 0.85, iterations: 30 };
    forall(
        &cfg(20),
        |rng, size| {
            // cycle + random chords: out-degree >= 1 everywhere
            let n = 2 + rng.below(size as u64 + 2) as usize;
            let mut el = nwgraph_hpx::graph::EdgeList::new(n);
            for i in 0..n {
                el.push(i as u32, ((i + 1) % n) as u32);
            }
            for _ in 0..n {
                let u = rng.below(n as u64) as u32;
                let v = rng.below(n as u64) as u32;
                if u != v {
                    el.push(u, v);
                }
            }
            el.dedup();
            let p = gen::locality_count(rng, size);
            (Csr::from_edge_list(&el), p)
        },
        |(g, p)| {
            let dist = DistGraph::block(g, *p);
            let res = pagerank::run_bsp(&dist, params, det());
            let sum: f32 = res.ranks.iter().sum();
            if (sum - 1.0).abs() > 1e-3 {
                return Err(format!("rank mass {sum} != 1"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cc_matches_union_find() {
    forall(
        &cfg(30),
        |rng, size| (gen::ugraph(rng, size), gen::locality_count(rng, size)),
        |(g, p)| {
            let dist = DistGraph::block(g, *p);
            let res = cc::run(&dist, det());
            let want = cc::union_find(g);
            if res.labels != want {
                return Err("labels differ from union-find".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sssp_matches_dijkstra() {
    forall(
        &cfg(25),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let gw = generators::with_random_weights(&g, 0.5, 9.5, rng.next_u64());
            let p = gen::locality_count(rng, size);
            let root = rng.below(g.n() as u64) as u32;
            (gw, p, root)
        },
        |(gw, p, root)| {
            let dist = DistGraph::block(gw, *p);
            let want = sssp::dijkstra(gw, *root);
            for res in [
                sssp::run_async(gw, &dist, *root, det()),
                sssp::run_bsp(gw, &dist, *root, det()),
            ] {
                for v in 0..gw.n() {
                    let (a, b) = (res.dist[v], want[v]);
                    if !((a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3) {
                        return Err(format!("dist[{v}]: {a} vs {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_triangles_match_sequential() {
    forall(
        &cfg(25),
        |rng, size| (gen::ugraph(rng, size), gen::locality_count(rng, size)),
        |(g, p)| {
            let dist = DistGraph::block(g, *p);
            let got = triangle::run(&dist, det()).triangles;
            let want = triangle::count_sequential(g);
            if got != want {
                return Err(format!("{got} vs {want} triangles"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_results_independent_of_partition_count() {
    // The same graph must produce identical PageRank ranks (up to float
    // noise) regardless of how many localities it is split across.
    let params = PrParams { alpha: 0.85, iterations: 12 };
    forall(
        &cfg(15),
        |rng, size| gen::digraph(rng, size + 4),
        |g| {
            let base = pagerank::run_bsp(&DistGraph::block(g, 1), params, det());
            for p in [2u32, 3, 5, 8] {
                let r = pagerank::run_bsp(&DistGraph::block(g, p), params, det());
                let diff = pagerank::max_abs_diff(&r.ranks, &base.ranks);
                if diff > 1e-5 {
                    return Err(format!("p={p}: diff {diff}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_async_aggregated_bfs_levels_match_bsp_and_sequential() {
    // The cross-model agreement property: for random digraph-shaped
    // undirected graphs, random locality counts in [1, 8], and a random
    // flush policy, the aggregated asynchronous (level-correcting) BFS,
    // the BSP level-sync BFS, and the sequential oracle all produce the
    // same per-vertex levels.
    forall(
        &cfg(64),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let p = gen::locality_count(rng, size);
            let root = rng.below(g.n() as u64) as u32;
            let policy = gen_policy(rng);
            (g, p, root, policy)
        },
        |(g, p, root, policy)| {
            let dist = DistGraph::block(g, *p);
            let want = bfs::sequential::distances(g, *root);

            let async_res = bfs::run_async_with(&dist, *root, *policy, det());
            bfs::validate_parents(g, *root, &async_res.parents)?;
            let async_lv = bfs::tree_levels(*root, &async_res.parents);
            if async_lv != want {
                return Err(format!("async[{policy:?}] levels != sequential"));
            }

            let bsp_res = bfs::run_bsp(&dist, *root, det());
            let bsp_lv = bfs::tree_levels(*root, &bsp_res.parents);
            if bsp_lv != want {
                return Err("bsp levels != sequential".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_async_aggregated_pagerank_matches_sequential() {
    // Aggregation is a performance knob: for random digraphs, locality
    // counts in [1, 8], and random flush policies, asynchronous PageRank
    // ranks must match the sequential oracle within tolerance.
    let params = PrParams { alpha: 0.85, iterations: 10 };
    forall(
        &cfg(64),
        |rng, size| {
            let g = gen::digraph(rng, size);
            let p = gen::locality_count(rng, size);
            let policy = gen_policy(rng);
            (g, p, policy)
        },
        |(g, p, policy)| {
            let dist = DistGraph::block(g, *p);
            let want = pagerank::sequential::pagerank(g, params);
            let res = pagerank::run_async(&dist, params, *policy, det());
            let diff = pagerank::max_abs_diff(&res.ranks, &want);
            if diff > 1e-4 {
                return Err(format!("{policy:?}: diff {diff}"));
            }
            // Nothing the combiners absorbed may be lost: per-iteration
            // drains mean every folded or shipped item is accounted for.
            let agg = res.report.agg;
            if agg.items != agg.folded + agg.sent_items {
                return Err(format!("aggregation leak: {agg:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_aggregation_preserves_bfs_semantics() {
    // Coalescing/aggregation are performance knobs — results must not
    // change.
    forall(
        &cfg(20),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let p = gen::locality_count(rng, size);
            (g, p)
        },
        |(g, p)| {
            let dist = DistGraph::block(g, *p);
            let plain = bfs::run_async(&dist, 0, det());
            let packed = bfs::run_async(
                &dist,
                0,
                SimConfig {
                    aggregate_sends: true,
                    coalesce_window_us: 10.0,
                    ..det()
                },
            );
            bfs::validate_parents(g, 0, &packed.parents)?;
            for v in 0..g.n() {
                if (plain.parents[v] >= 0) != (packed.parents[v] >= 0) {
                    return Err(format!("reachability differs at {v}"));
                }
            }
            Ok(())
        },
    );
}
