//! Engine-equivalence property suite for the unified `VertexProgram` API.
//!
//! The contract under test: a [`VertexProgram`] is one algorithm, and
//! which engine runs it — asynchronous label-correcting, BSP supersteps,
//! or the ordered delta schedule — plus which partition scheme shards the
//! graph are pure *performance* knobs. Every ported program (BFS, SSSP,
//! PageRank, CC) must agree with its sequential oracle across
//! `{async, bsp, delta-where-applicable} × all 4 partition schemes ×
//! {1, 2, 4, 8}` localities on random graphs. The delta × vertex-cut cell
//! was gated before the engine redesign and is asserted here explicitly.
//!
//! Environment knobs (see `testing::PropConfig::from_env`):
//! `NWGRAPH_PROP_SEED` pins the base seed (the CI seed matrix);
//! `NWGRAPH_PROP_CASES` shrinks case counts for fast local runs.

use nwgraph_hpx::algorithms::{bfs, cc, pagerank, pagerank::PrParams, sssp};
use nwgraph_hpx::amt::{FlushPolicy, NetConfig, SimConfig};
use nwgraph_hpx::graph::generators::SplitMix64;
use nwgraph_hpx::graph::{generators, DistGraph, PartitionKind};
use nwgraph_hpx::testing::{forall, gen, PropConfig};

fn det() -> SimConfig {
    SimConfig::deterministic(NetConfig::default())
}

fn cfg(cases: u32) -> PropConfig {
    PropConfig::from_env(cases, 0xE1913E, 40)
}

const LOCALITIES: [u32; 4] = [1, 2, 4, 8];

/// Draw a flush policy from the interesting corners of the policy space —
/// including the time-window and latency-adaptive policies, so the oracle
/// properties race the poll/timer flush path and the ack-driven tuner
/// through every engine × scheme × locality combination.
fn gen_policy(rng: &mut SplitMix64) -> FlushPolicy {
    match rng.below(7) {
        0 => FlushPolicy::Unbatched,
        1 => FlushPolicy::Items(1 + rng.below(64) as usize),
        2 => FlushPolicy::Bytes(8 + rng.below(1024) as usize),
        3 => FlushPolicy::Adaptive,
        4 => FlushPolicy::TimeWindow(rng.below(30)),
        5 => FlushPolicy::LatencyAdaptive,
        _ => FlushPolicy::Manual,
    }
}

#[test]
fn prop_bfs_program_matches_oracle_on_every_engine_and_scheme() {
    forall(
        &cfg(24),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let root = rng.below(g.n() as u64) as u32;
            (g, root, gen_policy(rng))
        },
        |(g, root, policy)| {
            let want = bfs::sequential::distances(g, *root);
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    for (engine, res) in [
                        ("async", bfs::run_async_with(&dist, *root, *policy, det())),
                        ("bsp", bfs::run_bsp(&dist, *root, det())),
                    ] {
                        bfs::validate_parents(g, *root, &res.parents)?;
                        if bfs::tree_levels(*root, &res.parents) != want {
                            return Err(format!("bfs {engine} {kind:?} p={p}: levels diverge"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sssp_program_matches_oracle_on_every_engine_and_scheme() {
    // Three engines × four schemes — including the previously gated
    // delta × vertex_cut combination, at a random Δ per case.
    forall(
        &cfg(16),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let gw = generators::with_random_weights(&g, 0.5, 9.5, rng.next_u64());
            let root = rng.below(gw.n() as u64) as u32;
            let delta = match rng.below(4) {
                0 => 0.3,
                1 => 1.5,
                2 => 6.0,
                _ => f32::INFINITY,
            };
            (gw, root, gen_policy(rng), delta)
        },
        |(gw, root, policy, delta)| {
            let want = sssp::dijkstra(gw, *root);
            let close = |got: &[f32]| {
                got.iter().zip(&want).all(|(a, b)| {
                    (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3
                })
            };
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(gw, kind.build(gw, p));
                    if !close(&sssp::run_async_with(gw, &dist, *root, *policy, det()).dist) {
                        return Err(format!("sssp async {kind:?} p={p} {policy:?}"));
                    }
                    if !close(&sssp::run_bsp(gw, &dist, *root, det()).dist) {
                        return Err(format!("sssp bsp {kind:?} p={p}"));
                    }
                    if !close(
                        &sssp::run_delta_with(gw, &dist, *root, *delta, *policy, det()).dist,
                    ) {
                        return Err(format!("sssp delta={delta} {kind:?} p={p} {policy:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pagerank_program_matches_oracle_on_every_engine_and_scheme() {
    let params = PrParams { alpha: 0.85, iterations: 10 };
    forall(
        &cfg(16),
        |rng, size| (gen::digraph(rng, size), gen_policy(rng)),
        |(g, policy)| {
            let want = pagerank::sequential::pagerank(g, params);
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    for (engine, res) in [
                        ("async", pagerank::run_async(&dist, params, *policy, det())),
                        ("bsp", pagerank::run_bsp(&dist, params, det())),
                    ] {
                        let diff = pagerank::max_abs_diff(&res.ranks, &want);
                        if diff > 1e-4 {
                            return Err(format!(
                                "pagerank {engine} {kind:?} p={p} {policy:?}: diff {diff}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cc_program_matches_oracle_on_every_engine_and_scheme() {
    forall(
        &cfg(24),
        |rng, size| (gen::ugraph(rng, size), gen_policy(rng)),
        |(g, policy)| {
            let want = cc::union_find(g);
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    if cc::run(&dist, det()).labels != want {
                        return Err(format!("cc bsp {kind:?} p={p}: labels diverge"));
                    }
                    if cc::run_async(&dist, *policy, det()).labels != want {
                        return Err(format!("cc async {kind:?} p={p} {policy:?}: diverge"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn delta_sssp_under_vertex_cut_on_benchmark_rmat() {
    // Acceptance pin for the previously gated cell at benchmark shape: a
    // skewed kron graph whose vertex cut really mirrors, 8 localities,
    // several Δ including the Bellman-Ford and Dijkstra-like extremes.
    let seed = cfg(1).seed; // honors NWGRAPH_PROP_SEED via from_env
    let g = generators::kron(9, 8, seed);
    let gw = generators::with_random_weights(&g, 1.0, 10.0, seed + 1);
    let dist = DistGraph::build_with(&gw, PartitionKind::VertexCut.build(&gw, 8));
    assert!(dist.has_mirrors(), "kron9@8 vertex cut should mirror");
    let want = sssp::dijkstra(&gw, 0);
    for delta in [0.2f32, sssp::auto_delta(&gw), f32::INFINITY] {
        let res = sssp::run_delta_with(&gw, &dist, 0, delta, FlushPolicy::Adaptive, det());
        for v in 0..gw.n() {
            let (a, b) = (res.dist[v], want[v]);
            assert!(
                (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3,
                "delta={delta} dist[{v}]: {a} vs {b}"
            );
        }
        assert!(res.report.partition.replication_factor > 1.0);
    }
}

#[test]
fn latency_adaptive_beats_static_adaptive_on_benchmark_rmat() {
    // A7 acceptance pin (release CI runs this suite): on the benchmark
    // kron10@8 vertex cut, the latency-observing policy must emit at most
    // as many envelopes as the static break-even policy for bfs-async and
    // sssp-delta — the tuner starts at the static threshold and only
    // moves within [break-even, 64x], so it can merge more, never less —
    // and its per-slot-space observed-latency columns must be populated.
    let seed = cfg(1).seed; // honors NWGRAPH_PROP_SEED via from_env
    let g = generators::kron(10, 8, seed);
    let dist = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 8));
    assert!(dist.has_mirrors(), "kron10@8 vertex cut should mirror");

    let want = bfs::sequential::distances(&g, 0);
    let stat = bfs::run_async_with(&dist, 0, FlushPolicy::Adaptive, det());
    let lat = bfs::run_async_with(&dist, 0, FlushPolicy::LatencyAdaptive, det());
    for r in [&stat, &lat] {
        assert_eq!(bfs::tree_levels(0, &r.parents), want, "bfs levels diverge");
    }
    assert!(
        lat.report.agg.envelopes <= stat.report.agg.envelopes,
        "bfs-async: latency-adaptive {} vs static adaptive {} envelopes",
        lat.report.agg.envelopes,
        stat.report.agg.envelopes
    );
    assert!(lat.report.agg_master.acks > 0, "master-bound latency unobserved");
    assert!(lat.report.agg_mirror.acks > 0, "mirror-bound latency unobserved");
    assert!(lat.report.agg_master.mean_obs_latency_us() > 0.0);
    assert!(lat.report.agg_mirror.mean_obs_latency_us() > 0.0);

    let gw = generators::with_random_weights(&g, 1.0, 10.0, seed + 1);
    let distw = DistGraph::build_with(&gw, PartitionKind::VertexCut.build(&gw, 8));
    let delta = sssp::auto_delta(&gw);
    let want = sssp::dijkstra(&gw, 0);
    let stat = sssp::run_delta_with(&gw, &distw, 0, delta, FlushPolicy::Adaptive, det());
    let lat = sssp::run_delta_with(&gw, &distw, 0, delta, FlushPolicy::LatencyAdaptive, det());
    for r in [&stat, &lat] {
        for v in 0..gw.n() {
            let (a, b) = (r.dist[v], want[v]);
            assert!(
                (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3,
                "sssp dist[{v}]: {a} vs {b}"
            );
        }
    }
    assert!(
        lat.report.agg.envelopes <= stat.report.agg.envelopes,
        "sssp-delta: latency-adaptive {} vs static adaptive {} envelopes",
        lat.report.agg.envelopes,
        stat.report.agg.envelopes
    );
    assert!(lat.report.agg.acks > 0, "delta estimator never observed a delivery");
}

#[test]
fn engines_share_one_aggregation_layer() {
    // The engines, not the programs, own combiner accounting: for every
    // program × engine pair, whatever was accumulated is folded or
    // shipped (nothing leaks), and batches ship as exactly one envelope
    // each wherever no control traffic exists (async engines).
    let g = generators::urand(6, 4, 7);
    let gw = generators::with_random_weights(&g, 1.0, 10.0, 8);
    let gd = generators::urand_directed(6, 4, 9);
    let dist = DistGraph::block(&g, 4);
    let distw = DistGraph::block(&gw, 4);
    let distd = DistGraph::block(&gd, 4);
    let params = PrParams { alpha: 0.85, iterations: 5 };
    let reports = [
        ("bfs-async", bfs::run_async(&dist, 0, det()).report),
        ("bfs-bsp", bfs::run_bsp(&dist, 0, det()).report),
        ("sssp-async", sssp::run_async(&gw, &distw, 0, det()).report),
        ("sssp-bsp", sssp::run_bsp(&gw, &distw, 0, det()).report),
        ("sssp-delta", sssp::run_delta(&gw, &distw, 0, det()).report),
        (
            "pr-async",
            pagerank::run_async(&distd, params, FlushPolicy::Adaptive, det()).report,
        ),
        ("pr-bsp", pagerank::run_bsp(&distd, params, det()).report),
        ("cc-bsp", cc::run(&dist, det()).report),
        ("cc-async", cc::run_async(&dist, FlushPolicy::Adaptive, det()).report),
    ];
    for (name, r) in reports {
        assert_eq!(r.agg.items, r.agg.folded + r.agg.sent_items, "{name}: leak {:?}", r.agg);
        assert_eq!(
            r.agg.envelopes,
            r.agg.policy_flushes + r.agg.drain_flushes,
            "{name}: {:?}",
            r.agg
        );
        // The per-slot-space split partitions the merged stats exactly.
        let mut merged = r.agg_master;
        merged.merge(&r.agg_mirror);
        assert_eq!(merged, r.agg, "{name}: slot-space split does not sum");
        if name.ends_with("async") {
            assert_eq!(r.agg.envelopes, r.net.envelopes, "{name}: {:?}", r.agg);
            assert_eq!(r.barriers, if name.starts_with("pr") { 5 } else { 0 }, "{name}");
        }
    }
}
