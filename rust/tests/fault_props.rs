//! Fault-injection property suite: reliable delivery and checkpoint/restart.
//!
//! The contract under test: the seeded [`FaultPlan`] (envelope drops,
//! duplicates, extra delays, fail-stop crashes) is a pure *adversary* knob.
//! Under `reliability=acked` every algorithm must reach the same oracle
//! fixpoint it reaches on a perfect network — sequence numbers, receiver
//! dedup, and ack-driven retransmit mask the wire faults, and the
//! checkpoint/restart path masks a mid-run crash. Conversely, with
//! `FaultPlan::none` the machinery must cost nothing: `reliability=none`
//! keeps the fault counters quiet, and `reliability=acked` keeps *exact
//! envelope parity* with the unreliable fast path (acks are delivery
//! reports, not envelopes; with nothing dropped, nothing retransmits).
//!
//! Environment knobs (see `testing::PropConfig::from_env`):
//! `NWGRAPH_PROP_SEED` pins the base seed (the CI seed matrix);
//! `NWGRAPH_PROP_CASES` shrinks case counts for fast local runs.

use nwgraph_hpx::algorithms::{bfs, cc, pagerank, pagerank::PrParams, sssp};
use nwgraph_hpx::amt::{FaultPlan, FlushPolicy, NetConfig, Reliability, RuntimeKind, SimConfig};
use nwgraph_hpx::graph::generators::SplitMix64;
use nwgraph_hpx::graph::{generators, DistGraph, PartitionKind};
use nwgraph_hpx::testing::{forall, gen, PropConfig};

fn det() -> SimConfig {
    SimConfig::deterministic(NetConfig::default())
}

fn acked(fault: FaultPlan) -> SimConfig {
    SimConfig { fault, reliability: Reliability::Acked, ..det() }
}

fn cfg(cases: u32) -> PropConfig {
    PropConfig::from_env(cases, 0xFA17, 32)
}

const LOCALITIES: [u32; 4] = [1, 2, 4, 8];

/// Draw a chaos plan: drop/duplicate probabilities up to ~8%, extra
/// delivery delays up to 8 µs, independent decision seed. Occasionally a
/// straggler (sim only — the threads runtime ignores compute charges).
fn gen_chaos(rng: &mut SplitMix64, with_slow: bool, p: u32) -> FaultPlan {
    FaultPlan {
        drop_p: rng.below(9) as f64 / 100.0,
        dup_p: rng.below(9) as f64 / 100.0,
        delay_us: rng.below(9) as f64,
        crash: None,
        slow: if with_slow && rng.below(4) == 0 {
            Some((rng.below(p as u64) as u32, 1.0 + rng.below(4) as f64))
        } else {
            None
        },
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_acked_reliability_is_free_without_faults() {
    // Overhead parity: on a perfect wire the reliable layer may stamp
    // sequence numbers and request acks, but it must not change *what*
    // ships — same answers, same aggregator envelope count, same on-wire
    // envelope count, and zero retransmit/dedup/give-up activity. The
    // unreliable run additionally keeps every fault counter at zero.
    forall(
        &cfg(12),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let root = rng.below(g.n() as u64) as u32;
            (g, root)
        },
        |(g, root)| {
            let want = bfs::sequential::distances(g, *root);
            for kind in [PartitionKind::Block, PartitionKind::VertexCut] {
                for p in [2, 4] {
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    let plain = bfs::run_async_with(&dist, *root, FlushPolicy::Adaptive, det());
                    let rel = bfs::run_async_with(
                        &dist,
                        *root,
                        FlushPolicy::Adaptive,
                        acked(FaultPlan::none()),
                    );
                    for (name, r) in [("none", &plain), ("acked", &rel)] {
                        if bfs::tree_levels(*root, &r.parents) != want {
                            return Err(format!("bfs {name} {kind:?} p={p}: levels diverge"));
                        }
                    }
                    if !plain.report.fault.is_quiet() {
                        return Err(format!(
                            "reliability=none {kind:?} p={p}: fault counters not quiet: {:?}",
                            plain.report.fault
                        ));
                    }
                    let f = &rel.report.fault;
                    if f.retransmits + f.dedup_hits + f.give_ups != 0 {
                        return Err(format!(
                            "acked w/o faults {kind:?} p={p}: spurious reliability work {f:?}"
                        ));
                    }
                    if rel.report.agg.envelopes != plain.report.agg.envelopes
                        || rel.report.net.envelopes != plain.report.net.envelopes
                    {
                        return Err(format!(
                            "acked w/o faults {kind:?} p={p}: envelope parity broken \
                             (agg {} vs {}, net {} vs {})",
                            rel.report.agg.envelopes,
                            plain.report.agg.envelopes,
                            rel.report.net.envelopes,
                            plain.report.net.envelopes
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_chaos_faults_masked_by_acked_reliability_on_sim() {
    // The chaos sweep: random drop/dup/delay (and the odd straggler) ×
    // all four partition schemes × {1, 2, 4, 8} localities, one engine
    // per protocol family — async Converge (BFS), ordered delta (SSSP),
    // BSP Converge (CC), BSP Iterate (PageRank). Every cell must still
    // equal its sequential oracle.
    let params = PrParams { alpha: 0.85, iterations: 8 };
    forall(
        &cfg(8),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let gw = generators::with_random_weights(&g, 0.5, 9.5, rng.next_u64());
            let root = rng.below(g.n() as u64) as u32;
            let plan = gen_chaos(rng, true, 8);
            (g, gw, root, plan)
        },
        |(g, gw, root, plan)| {
            let bfs_want = bfs::sequential::distances(g, *root);
            let sssp_want = sssp::dijkstra(gw, *root);
            let cc_want = cc::union_find(g);
            let pr_want = pagerank::sequential::pagerank(g, params);
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let c = acked(plan.clone());
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    let distw = DistGraph::build_with(gw, kind.build(gw, p));
                    let b = bfs::run_async_with(&dist, *root, FlushPolicy::Adaptive, c.clone());
                    if bfs::tree_levels(*root, &b.parents) != bfs_want {
                        return Err(format!("bfs-async {kind:?} p={p}: levels diverge"));
                    }
                    let s = sssp::run_delta_with(
                        gw,
                        &distw,
                        *root,
                        sssp::auto_delta(gw),
                        FlushPolicy::Adaptive,
                        c.clone(),
                    );
                    if !s.dist.iter().zip(&sssp_want).all(|(a, b)| {
                        (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3
                    }) {
                        return Err(format!("sssp-delta {kind:?} p={p}: distances diverge"));
                    }
                    if cc::run(&dist, c.clone()).labels != cc_want {
                        return Err(format!("cc-bsp {kind:?} p={p}: labels diverge"));
                    }
                    let r = pagerank::run_bsp(&dist, params, c);
                    let diff = pagerank::max_abs_diff(&r.ranks, &pr_want);
                    if diff > 1e-4 {
                        return Err(format!("pagerank-bsp {kind:?} p={p}: diff {diff}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_chaos_faults_masked_by_acked_reliability_on_threads() {
    // Same adversary on the real-thread substrate: wire faults are
    // injected at the inbox seam and retransmit timers run on wall
    // clock, so the interleavings are genuinely nondeterministic — the
    // *answers* must not be.
    let params = PrParams { alpha: 0.85, iterations: 8 };
    forall(
        &cfg(6),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let gw = generators::with_random_weights(&g, 0.5, 9.5, rng.next_u64());
            let root = rng.below(g.n() as u64) as u32;
            let plan = gen_chaos(rng, false, 4);
            (g, gw, root, plan)
        },
        |(g, gw, root, plan)| {
            let bfs_want = bfs::sequential::distances(g, *root);
            let sssp_want = sssp::dijkstra(gw, *root);
            let cc_want = cc::union_find(g);
            let pr_want = pagerank::sequential::pagerank(g, params);
            for kind in PartitionKind::all() {
                for p in [2, 4] {
                    let c = SimConfig {
                        runtime: RuntimeKind::Threads,
                        ..acked(plan.clone())
                    };
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    let distw = DistGraph::build_with(gw, kind.build(gw, p));
                    let b = bfs::run_async_with(&dist, *root, FlushPolicy::Adaptive, c.clone());
                    if bfs::tree_levels(*root, &b.parents) != bfs_want {
                        return Err(format!("bfs-async {kind:?} p={p}: levels diverge"));
                    }
                    let s = sssp::run_delta_with(
                        gw,
                        &distw,
                        *root,
                        sssp::auto_delta(gw),
                        FlushPolicy::Adaptive,
                        c.clone(),
                    );
                    if !s.dist.iter().zip(&sssp_want).all(|(a, b)| {
                        (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3
                    }) {
                        return Err(format!("sssp-delta {kind:?} p={p}: distances diverge"));
                    }
                    if cc::run(&dist, c.clone()).labels != cc_want {
                        return Err(format!("cc-bsp {kind:?} p={p}: labels diverge"));
                    }
                    let r = pagerank::run_bsp(&dist, params, c);
                    let diff = pagerank::max_abs_diff(&r.ranks, &pr_want);
                    if diff > 1e-4 {
                        return Err(format!("pagerank-bsp {kind:?} p={p}: diff {diff}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn chaos_pin_injects_and_masks_on_benchmark_kron() {
    // Acceptance pin at benchmark shape: a skewed kron graph under a hot
    // adversary (15% drop, 15% dup, jitter) actually *exercises* the
    // reliable layer — injection and recovery counters are all nonzero —
    // while BFS stays oracle-exact. Holds for any base seed: at hundreds
    // of envelopes, the per-envelope fault draws can't all miss.
    let seed = cfg(1).seed; // honors NWGRAPH_PROP_SEED via from_env
    let g = generators::kron(9, 8, seed);
    let dist = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 8));
    let plan = FaultPlan {
        drop_p: 0.15,
        dup_p: 0.15,
        delay_us: 4.0,
        crash: None,
        slow: None,
        seed: seed ^ 0xC4A05,
    };
    let res = bfs::run_async_with(&dist, 0, FlushPolicy::Adaptive, acked(plan));
    assert_eq!(
        bfs::tree_levels(0, &res.parents),
        bfs::sequential::distances(&g, 0),
        "chaos run diverged from the oracle"
    );
    let f = &res.report.fault;
    assert!(f.injected_drops > 0, "adversary never dropped: {f:?}");
    assert!(f.injected_dups > 0, "adversary never duplicated: {f:?}");
    assert!(f.injected_delays > 0, "adversary never delayed: {f:?}");
    assert!(f.retransmits > 0, "drops were never repaired: {f:?}");
    assert!(f.dedup_hits > 0, "dups/retransmits were never deduped: {f:?}");
    assert_eq!(f.crashes + f.restores, 0, "no crash was planned: {f:?}");
}

#[test]
fn crash_and_restore_reconverge_to_the_oracle() {
    // Checkpoint/restart across every engine recovery path: async
    // Converge (BFS), async Iterate (PageRank-async), BSP Converge
    // (BFS-BSP), BSP Iterate (PageRank-BSP), and the ordered delta
    // schedule (SSSP). Each run first probes its fault-free makespan,
    // then replays with locality p-1 fail-stopping at half of it — a
    // guaranteed mid-run crash — and must still reach the oracle, with
    // the crash, checkpoint, and restore counters all engaged.
    let seed = cfg(1).seed; // honors NWGRAPH_PROP_SEED via from_env
    let p = 4u32;
    let g = generators::kron(8, 8, seed);
    let gw = generators::with_random_weights(&g, 1.0, 10.0, seed + 1);
    let dist = DistGraph::build_with(&g, PartitionKind::Block.build(&g, p));
    let distw = DistGraph::build_with(&gw, PartitionKind::Block.build(&gw, p));
    let params = PrParams { alpha: 0.85, iterations: 8 };

    let crash_cfg = |makespan_us: f64| {
        let mut c = acked(FaultPlan::none());
        c.fault.crash = Some((p - 1, (makespan_us * 0.5).max(1.0)));
        c
    };
    let check = |name: &str, report: &nwgraph_hpx::amt::SimReport| {
        let f = &report.fault;
        assert!(f.crashes > 0, "{name}: planned crash never fired: {f:?}");
        assert!(f.restores > 0, "{name}: no checkpoint restore: {f:?}");
        assert!(f.checkpoints > 0, "{name}: no snapshots taken: {f:?}");
        assert!(f.recovery_wall_us > 0.0, "{name}: recovery did no work: {f:?}");
    };

    let bfs_want = bfs::sequential::distances(&g, 0);
    let probe = bfs::run_async(&dist, 0, det());
    let r = bfs::run_async_with(
        &dist,
        0,
        FlushPolicy::Adaptive,
        crash_cfg(probe.report.makespan_us),
    );
    assert_eq!(bfs::tree_levels(0, &r.parents), bfs_want, "bfs-async post-crash");
    check("bfs-async", &r.report);

    let probe = bfs::run_bsp(&dist, 0, det());
    let r = bfs::run_bsp(&dist, 0, crash_cfg(probe.report.makespan_us));
    assert_eq!(bfs::tree_levels(0, &r.parents), bfs_want, "bfs-bsp post-crash");
    check("bfs-bsp", &r.report);

    let sssp_want = sssp::dijkstra(&gw, 0);
    let probe = sssp::run_delta(&gw, &distw, 0, det());
    let r = sssp::run_delta(&gw, &distw, 0, crash_cfg(probe.report.makespan_us));
    for v in 0..gw.n() {
        let (a, b) = (r.dist[v], sssp_want[v]);
        assert!(
            (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3,
            "sssp-delta post-crash dist[{v}]: {a} vs {b}"
        );
    }
    check("sssp-delta", &r.report);

    let pr_want = pagerank::sequential::pagerank(&g, params);
    let probe = pagerank::run_bsp(&dist, params, det());
    let r = pagerank::run_bsp(&dist, params, crash_cfg(probe.report.makespan_us));
    assert!(
        pagerank::max_abs_diff(&r.ranks, &pr_want) < 1e-4,
        "pagerank-bsp post-crash diverged"
    );
    check("pagerank-bsp", &r.report);

    let probe = pagerank::run_async(&dist, params, FlushPolicy::Adaptive, det());
    let r = pagerank::run_async(
        &dist,
        params,
        FlushPolicy::Adaptive,
        crash_cfg(probe.report.makespan_us),
    );
    assert!(
        pagerank::max_abs_diff(&r.ranks, &pr_want) < 1e-4,
        "pagerank-async post-crash diverged"
    );
    check("pagerank-async", &r.report);
}

#[test]
fn prop_crash_recovery_on_random_graphs() {
    // Crash/restore is not a benchmark-shape special case: on random
    // graphs, random block/cut schemes, and random crash fractions the
    // restored run still reaches the BFS oracle. (Small graphs may
    // finish before the crash time — then the plan stays un-fired and
    // the run must simply be correct and quiet about restores.)
    forall(
        &cfg(10),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let root = rng.below(g.n() as u64) as u32;
            let frac = 0.25 + rng.below(50) as f64 / 100.0; // 0.25..0.75
            let kind = if rng.below(2) == 0 {
                PartitionKind::Block
            } else {
                PartitionKind::VertexCut
            };
            (g, root, frac, kind)
        },
        |(g, root, frac, kind)| {
            let want = bfs::sequential::distances(g, *root);
            for p in [2, 4] {
                let dist = DistGraph::build_with(g, kind.build(g, p));
                let probe = bfs::run_async(&dist, *root, det());
                let mut c = acked(FaultPlan::none());
                c.fault.crash = Some((p - 1, (probe.report.makespan_us * frac).max(1.0)));
                let r = bfs::run_async_with(&dist, *root, FlushPolicy::Adaptive, c);
                if bfs::tree_levels(*root, &r.parents) != want {
                    return Err(format!("bfs {kind:?} p={p} frac={frac}: diverged"));
                }
                let f = &r.report.fault;
                if f.crashes > 0 && f.restores == 0 {
                    return Err(format!("{kind:?} p={p}: crash fired but never restored"));
                }
                if f.crashes == 0 && (f.restores > 0 || f.recovery_wall_us > 0.0) {
                    return Err(format!("{kind:?} p={p}: phantom recovery: {f:?}"));
                }
            }
            Ok(())
        },
    );
}
