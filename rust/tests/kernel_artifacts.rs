//! Integration tests over the AOT artifacts (three-layer path).
//!
//! These need `make artifacts` to have run; they skip (with a note) when
//! `artifacts/manifest.txt` is absent so `cargo test` works standalone.

use std::sync::{Arc, Mutex};

use nwgraph_hpx::algorithms::pagerank::{self, PrParams};
use nwgraph_hpx::amt::{NetConfig, SimConfig};
use nwgraph_hpx::graph::{generators, DistGraph};
use nwgraph_hpx::runtime::Engine;

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn engine_loads_and_reports_platform() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(dir).expect("engine load");
    assert!(!engine.manifest().specs().is_empty());
    assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());
}

#[test]
fn pagerank_step_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(dir).expect("engine load");
    let spec = engine.prepare("pagerank", 1024, 1024).expect("no artifact");

    // Random masked ELL + identity row_map; compare against a scalar rust
    // evaluation of the same contract.
    let mut rng = generators::SplitMix64::new(7);
    let g = spec.n_global;
    let r = spec.n_rows;
    let d = spec.max_deg;
    let contrib: Vec<f32> = (0..g).map(|_| rng.f64() as f32).collect();
    let rank_old: Vec<f32> = (0..r).map(|_| rng.f64() as f32).collect();
    let cols: Vec<i32> = (0..r * d).map(|_| rng.below(g as u64) as i32).collect();
    let mask: Vec<f32> = (0..r * d).map(|_| (rng.below(2)) as f32).collect();
    let row_map: Vec<i32> = (0..r as i32).collect();
    let (base, alpha) = (0.15f32 / r as f32, 0.85f32);

    let (got_rank, got_delta) = engine
        .pagerank_step(&spec, &contrib, &rank_old, &cols, &mask, &row_map, base, alpha)
        .expect("kernel exec");

    let mut want_delta = 0.0f32;
    for i in 0..r {
        let mut z = 0.0f32;
        for k in 0..d {
            z += contrib[cols[i * d + k] as usize] * mask[i * d + k];
        }
        let new = base + alpha * z;
        assert!(
            (got_rank[i] - new).abs() < 1e-4,
            "row {i}: kernel {} vs rust {}",
            got_rank[i],
            new
        );
        want_delta += (new - rank_old[i]).abs();
    }
    // L1 over thousands of rows accumulates in different orders.
    assert!(
        (got_delta - want_delta).abs() / want_delta.max(1.0) < 1e-3,
        "delta {got_delta} vs {want_delta}"
    );
}

#[test]
fn bfs_level_kernel_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::load(dir).expect("engine load");
    let spec = engine.prepare("bfs", 1024, 1024).expect("no artifact");

    let mut rng = generators::SplitMix64::new(11);
    let g = spec.n_global;
    let r = spec.n_rows;
    let d = spec.max_deg;
    let frontier: Vec<f32> = (0..g).map(|_| (rng.below(4) == 0) as u32 as f32).collect();
    let visited: Vec<f32> = (0..r).map(|_| (rng.below(3) == 0) as u32 as f32).collect();
    let cols: Vec<i32> = (0..r * d).map(|_| rng.below(g as u64) as i32).collect();
    let mask: Vec<f32> = (0..r * d).map(|_| (rng.below(2)) as f32).collect();

    let (next, parents) = engine
        .bfs_level(&spec, &frontier, &visited, &cols, &mask)
        .expect("kernel exec");

    for i in 0..r {
        let mut any = false;
        for k in 0..d {
            let c = cols[i * d + k] as usize;
            if mask[i * d + k] > 0.0 && frontier[c] > 0.0 {
                any = true;
            }
        }
        let want_next = if any && visited[i] == 0.0 { 1.0 } else { 0.0 };
        assert_eq!(next[i], want_next, "row {i}");
        if want_next > 0.0 {
            let p = parents[i];
            assert!(p >= 0, "row {i} discovered but parent {p}");
            assert_eq!(frontier[p as usize], 1.0, "row {i} parent not in frontier");
        } else {
            assert_eq!(parents[i], -1, "row {i}");
        }
    }
}

#[test]
fn kernel_pagerank_end_to_end_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Arc::new(Mutex::new(Engine::load(dir).expect("engine load")));
    let params = PrParams { alpha: 0.85, iterations: 12 };
    for p in [1u32, 2, 4] {
        let g = generators::urand_directed(8, 6, 5 + p as u64);
        let want = pagerank::sequential::pagerank(&g, params);
        let dist = DistGraph::block(&g, p);
        let res = pagerank::kernel::run(
            &dist,
            params,
            SimConfig::deterministic(NetConfig::default()),
            engine.clone(),
        )
        .expect("kernel pagerank");
        let diff = pagerank::max_abs_diff(&res.ranks, &want);
        assert!(diff < 1e-4, "p={p}: diff {diff}");
        // allgather traffic: P*(P-1) slices per iteration
        assert_eq!(
            res.report.net.envelopes,
            (p as u64) * (p as u64 - 1) * params.iterations as u64
        );
    }
}

#[test]
fn kernel_pagerank_handles_wide_rows_via_splitting() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Arc::new(Mutex::new(Engine::load(dir).expect("engine load")));
    let params = PrParams { alpha: 0.85, iterations: 8 };
    // Star graph: the hub's in-degree (n-1) far exceeds any artifact
    // max_deg, forcing virtual-row splitting.
    let g = generators::star(512);
    let want = pagerank::sequential::pagerank(&g, params);
    let dist = DistGraph::block(&g, 2);
    let res = pagerank::kernel::run(
        &dist,
        params,
        SimConfig::deterministic(NetConfig::default()),
        engine,
    )
    .expect("kernel pagerank");
    let diff = pagerank::max_abs_diff(&res.ranks, &want);
    assert!(diff < 1e-4, "diff {diff}");
}
