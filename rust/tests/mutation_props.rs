//! Dynamic-graph property suite.
//!
//! The contract under test: applying an [`UpdateBatch`] through
//! [`DistGraph::apply_updates`]'s distributed scatter path and
//! re-converging with [`rerun_incremental`] must land on exactly the
//! answers a from-scratch run computes on the sequentially updated graph
//! ([`mutation::apply_to_csr`]) — across all 4 partition schemes ×
//! {1, 2, 4, 8} localities × {plain, compressed} storage × {sim, threads}
//! runtimes, for random insert/delete mixes. 1-D schemes go further: the
//! mutated shards must be *deeply equal* to a fresh build of the updated
//! graph under the same partition. The kron pins at the bottom hold the
//! PR acceptance line: incremental re-convergence strictly beats the full
//! recompute on relaxations and envelopes for small batches.
//!
//! Environment knobs (see `testing::PropConfig::from_env`):
//! `NWGRAPH_PROP_SEED` pins the base seed (the CI seed matrix);
//! `NWGRAPH_PROP_CASES` shrinks case counts for fast local runs.

use nwgraph_hpx::algorithms::{bfs, cc, pagerank, pagerank::PrParams, sssp};
use nwgraph_hpx::amt::{FlushPolicy, NetConfig, RuntimeKind, SimConfig};
use nwgraph_hpx::engine::{rerun_incremental, run_async, run_bsp, Reconverge};
use nwgraph_hpx::graph::generators::{self, SplitMix64};
use nwgraph_hpx::graph::{
    mutation, Csr, DistGraph, PartitionKind, StorageKind, UpdateBatch, VertexId,
};
use nwgraph_hpx::testing::{forall, gen, PropConfig};

fn det() -> SimConfig {
    SimConfig::deterministic(NetConfig::default())
}

fn threads() -> SimConfig {
    SimConfig { runtime: RuntimeKind::Threads, ..det() }
}

fn cfg(cases: u32) -> PropConfig {
    PropConfig::from_env(cases, 0x4D757461, 40)
}

fn build(g: &Csr, kind: PartitionKind, p: u32, storage: StorageKind) -> DistGraph {
    DistGraph::build_with_storage(g, kind.build(g, p), storage)
}

/// One generated dynamic-graph scenario.
#[derive(Debug)]
struct MutCase {
    g: Csr,
    batch: UpdateBatch,
    kind: PartitionKind,
    p: u32,
    storage: StorageKind,
    root: VertexId,
}

/// Random weighted symmetric graph + random batch + random deployment
/// shape (scheme × locality count × storage).
fn mut_case(rng: &mut SplitMix64, size: usize) -> MutCase {
    let base = gen::ugraph(rng, size);
    let g = generators::with_symmetric_random_weights(&base, 1.0, 10.0, rng.next_u64());
    let frac = 0.05 + rng.f64() * 0.3;
    let batch = mutation::generate_batch(&g, frac, rng.f64(), rng.next_u64(), true);
    let kind = PartitionKind::all()[rng.below(4) as usize];
    let p = [1u32, 2, 4, 8][rng.below(4) as usize];
    let storage =
        if rng.below(2) == 0 { StorageKind::Plain } else { StorageKind::Compressed };
    let root = rng.below(g.n() as u64) as VertexId;
    MutCase { g, batch, kind, p, storage, root }
}

fn check_sssp(got: &[f32], want: &[f32], ctx: &str) -> Result<(), String> {
    for (v, (a, b)) in got.iter().zip(want).enumerate() {
        let ok = (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3;
        if !ok {
            return Err(format!("{ctx}: sssp diverges at v{v} ({a} vs {b})"));
        }
    }
    Ok(())
}

/// Run SSSP + BFS + CC incrementally on one case under `scfg` and compare
/// every answer against the sequential oracles on the updated graph. Also
/// cross-checks the [`UpdateStats`](nwgraph_hpx::amt::UpdateStats)
/// counters against the oracle's applied/retracted counts.
fn monotone_roundtrip(c: &MutCase, scfg: &SimConfig, ctx: &str) -> Result<(), String> {
    let (g2, applied, retracted) = mutation::apply_to_csr(&c.g, &c.batch);

    let mut d = build(&c.g, c.kind, c.p, c.storage);
    let prog = sssp::SsspProgram { source: c.root };
    let base = run_async(prog.clone(), &d, FlushPolicy::Adaptive, scfg.clone());
    let run = rerun_incremental(
        prog,
        &mut d,
        &base.states,
        &c.batch,
        Reconverge::Async(FlushPolicy::Adaptive),
        scfg.clone(),
    );
    let u = &run.report.update;
    if (u.applied, u.retracted) != (applied, retracted) {
        return Err(format!(
            "{ctx}: stats ({}, {}) != oracle ({applied}, {retracted})",
            u.applied, u.retracted
        ));
    }
    // Every effective op routes exactly 3 edits (out-row, in-row, degree).
    if u.route_items != 3 * (applied + retracted) {
        return Err(format!("{ctx}: route_items {} != 3x effective ops", u.route_items));
    }
    check_sssp(&run.states, &sssp::dijkstra(&g2, c.root), ctx)?;

    let mut d = build(&c.g, c.kind, c.p, c.storage);
    let prog = bfs::BfsProgram { root: c.root };
    let base = run_async(prog.clone(), &d, FlushPolicy::Adaptive, scfg.clone());
    let run = rerun_incremental(
        prog,
        &mut d,
        &base.states,
        &c.batch,
        Reconverge::Async(FlushPolicy::Adaptive),
        scfg.clone(),
    );
    let parents: Vec<i64> = run.states.iter().map(|s| s.parent).collect();
    bfs::validate_parents(&g2, c.root, &parents).map_err(|e| format!("{ctx}: bfs {e}"))?;

    let mut d = build(&c.g, c.kind, c.p, c.storage);
    let base = run_async(cc::CcProgram, &d, FlushPolicy::Adaptive, scfg.clone());
    let run = rerun_incremental(
        cc::CcProgram,
        &mut d,
        &base.states,
        &c.batch,
        Reconverge::Async(FlushPolicy::Adaptive),
        scfg.clone(),
    );
    if run.states != cc::union_find(&g2) {
        return Err(format!("{ctx}: cc labels diverge"));
    }
    Ok(())
}

#[test]
fn prop_incremental_matches_full_recompute_across_schemes() {
    forall(&cfg(24), mut_case, |c| {
        monotone_roundtrip(c, &det(), &format!("{:?}@{}/{:?}", c.kind, c.p, c.storage))
    });
}

#[test]
fn prop_incremental_matches_under_threads_runtime() {
    forall(&cfg(6), mut_case, |c| {
        monotone_roundtrip(
            c,
            &threads(),
            &format!("threads {:?}@{}/{:?}", c.kind, c.p, c.storage),
        )
    });
}

#[test]
fn prop_pagerank_warm_restart_matches_warm_oracle() {
    // Directed graphs, asymmetric batches; the incremental run restarts
    // its fixed iteration count on BSP from the previous ranks, so it
    // must match the sequential power iteration warm-started from the
    // same vector on the updated graph.
    forall(
        &cfg(16),
        |rng, size| {
            let g = gen::digraph(rng, size);
            let batch = mutation::generate_batch(&g, 0.2, rng.f64(), rng.next_u64(), false);
            let kind = PartitionKind::all()[rng.below(4) as usize];
            let p = [1u32, 2, 4, 8][rng.below(4) as usize];
            (g, batch, kind, p)
        },
        |(g, batch, kind, p)| {
            let params = PrParams { alpha: 0.85, iterations: 10 };
            let prog = pagerank::PrProgram { params, n: g.n() };
            let (g2, _, _) = mutation::apply_to_csr(g, batch);
            let mut d = build(g, *kind, *p, StorageKind::Plain);
            let base = run_bsp(prog.clone(), &d, det());
            let run =
                rerun_incremental(prog, &mut d, &base.states, batch, Reconverge::Bsp, det());
            let prev: Vec<f32> = base.states.iter().map(|s| s.rank).collect();
            let got: Vec<f32> = run.states.iter().map(|s| s.rank).collect();
            let want = pagerank::sequential::pagerank_warm(&g2, params, &prev);
            let diff = pagerank::max_abs_diff(&got, &want);
            if diff < 1e-4 {
                Ok(())
            } else {
                Err(format!("{kind:?}@{p}: warm pagerank off by {diff}"))
            }
        },
    );
}

#[test]
fn prop_one_dim_updates_equal_fresh_rebuild() {
    // 1-D schemes home whole rows, so the spliced shards must be deeply
    // equal to a fresh build of the updated graph under the *same*
    // partition (vertex cuts may legally home inserts differently).
    forall(&cfg(16), mut_case, |c| {
        let kind = match c.kind {
            PartitionKind::VertexCut => PartitionKind::Block,
            k => k,
        };
        let (g2, _, _) = mutation::apply_to_csr(&c.g, &c.batch);
        let mut d = build(&c.g, kind, c.p, c.storage);
        d.apply_updates(&c.batch, FlushPolicy::Adaptive, &NetConfig::default());
        let fresh = DistGraph::build_with_storage(&g2, d.partition.clone(), c.storage);
        if d.shards != fresh.shards {
            return Err(format!("{kind:?}@{}/{:?}: shards != fresh rebuild", c.p, c.storage));
        }
        if d.m() != fresh.m() {
            return Err(format!("{kind:?}@{}: m {} != {}", c.p, d.m(), fresh.m()));
        }
        Ok(())
    });
}

/// Shared deterministic scenario: a weighted symmetric kron graph over
/// 4 block shards, exercised by the edge-case tests below.
fn kron_case() -> (Csr, DistGraph) {
    let g = generators::with_symmetric_random_weights(&generators::kron(7, 5, 11), 1.0, 10.0, 6);
    let d = DistGraph::block(&g, 4);
    (g, d)
}

#[test]
fn delete_only_insert_only_and_noop_batches() {
    let (g, d0) = kron_case();
    let base = run_async(sssp::SsspProgram { source: 0 }, &d0, FlushPolicy::Adaptive, det());

    // Delete-only: taints, then recovers the oracle exactly.
    let del = mutation::generate_batch(&g, 0.08, 0.0, 13, true);
    let (g2, applied, retracted) = mutation::apply_to_csr(&g, &del);
    assert!(applied == 0 && retracted > 0);
    let mut d = d0.clone();
    let run = rerun_incremental(
        sssp::SsspProgram { source: 0 },
        &mut d,
        &base.states,
        &del,
        Reconverge::Async(FlushPolicy::Adaptive),
        det(),
    );
    check_sssp(&run.states, &sssp::dijkstra(&g2, 0), "delete-only").unwrap();

    // Insert-only: taint-free improvement.
    let ins = mutation::generate_batch(&g, 0.08, 1.0, 14, true);
    let (g2, applied, retracted) = mutation::apply_to_csr(&g, &ins);
    assert!(applied > 0 && retracted == 0);
    let mut d = d0.clone();
    let run = rerun_incremental(
        sssp::SsspProgram { source: 0 },
        &mut d,
        &base.states,
        &ins,
        Reconverge::Async(FlushPolicy::Adaptive),
        det(),
    );
    assert_eq!(run.report.update.tainted, 0, "inserts never taint");
    check_sssp(&run.states, &sssp::dijkstra(&g2, 0), "insert-only").unwrap();

    // No-op: insert a live edge, delete an absent one. Nothing applies,
    // the shards stay untouched, and the fixpoint survives.
    let (u, v) = {
        let u = (0..g.n() as VertexId).find(|&x| g.degree(x) > 0).unwrap();
        (u, g.neighbors(u)[0])
    };
    let (a, b) = (0..g.n() as VertexId)
        .flat_map(|a| (0..g.n() as VertexId).map(move |b| (a, b)))
        .find(|&(a, b)| a != b && !g.has_edge(a, b))
        .unwrap();
    let mut noop = UpdateBatch::new();
    noop.insert(u, v, 5.0);
    noop.delete(a, b);
    let mut d = d0.clone();
    let run = rerun_incremental(
        sssp::SsspProgram { source: 0 },
        &mut d,
        &base.states,
        &noop,
        Reconverge::Async(FlushPolicy::Adaptive),
        det(),
    );
    let u = &run.report.update;
    assert_eq!((u.applied, u.retracted, u.tainted), (0, 0, 0), "{u:?}");
    assert_eq!(d.shards, d0.shards, "no-op batch must not touch shards");
    assert_eq!(run.states, base.states, "no-op batch must keep the fixpoint");
}

#[test]
fn disconnecting_batch_unreaches_across_schemes() {
    // path 0-1-2-3-4-5, cut between 2 and 3: the far side must go
    // unreached (BFS) and split (CC) under every scheme and both storages.
    let g = generators::path(6);
    let mut batch = UpdateBatch::new();
    batch.delete(2, 3);
    batch.delete(3, 2);
    for kind in PartitionKind::all() {
        for storage in [StorageKind::Plain, StorageKind::Compressed] {
            let mut d = build(&g, kind, 3, storage);
            let base = run_async(bfs::BfsProgram { root: 0 }, &d, FlushPolicy::Adaptive, det());
            let run = rerun_incremental(
                bfs::BfsProgram { root: 0 },
                &mut d,
                &base.states,
                &batch,
                Reconverge::Async(FlushPolicy::Adaptive),
                det(),
            );
            let levels: Vec<u32> = run.states.iter().map(|s| s.level).collect();
            assert_eq!(
                levels,
                vec![0, 1, 2, u32::MAX, u32::MAX, u32::MAX],
                "{kind:?}/{storage:?}"
            );

            let mut d = build(&g, kind, 3, storage);
            let base = run_async(cc::CcProgram, &d, FlushPolicy::Adaptive, det());
            let run = rerun_incremental(
                cc::CcProgram,
                &mut d,
                &base.states,
                &batch,
                Reconverge::Async(FlushPolicy::Adaptive),
                det(),
            );
            assert_eq!(run.states, vec![0, 0, 0, 3, 3, 3], "{kind:?}/{storage:?}");
        }
    }
}

/// The PR acceptance pin: on kron10 at 8 localities, a ≤ 1% batch must
/// re-converge with strictly fewer relaxations *and* envelopes than a
/// full recompute on the updated graph — on both the contiguous block
/// partition and the 2-D vertex cut.
#[test]
fn incremental_strictly_beats_full_recompute_on_kron10() {
    let g = generators::with_symmetric_random_weights(&generators::kron(10, 8, 3), 1.0, 10.0, 4);
    let batch = mutation::generate_batch(&g, 0.01, 0.5, 21, true);
    let (g2, _, _) = mutation::apply_to_csr(&g, &batch);
    let want = sssp::dijkstra(&g2, 0);
    for kind in [PartitionKind::Block, PartitionKind::VertexCut] {
        let mut d = DistGraph::build_with(&g, kind.build(&g, 8));
        let base =
            run_async(sssp::SsspProgram { source: 0 }, &d, FlushPolicy::Adaptive, det());
        let run = rerun_incremental(
            sssp::SsspProgram { source: 0 },
            &mut d,
            &base.states,
            &batch,
            Reconverge::Async(FlushPolicy::Adaptive),
            det(),
        );
        check_sssp(&run.states, &want, &format!("{kind:?}")).unwrap();
        let full = run_async(
            sssp::SsspProgram { source: 0 },
            &DistGraph::build_with(&g2, kind.build(&g2, 8)),
            FlushPolicy::Adaptive,
            det(),
        );
        let u = &run.report.update;
        assert!(
            u.reconverge_relaxations < full.report.work.relaxations,
            "{kind:?}: relax {} !< {}",
            u.reconverge_relaxations,
            full.report.work.relaxations
        );
        assert!(
            u.reconverge_envelopes < full.report.net.envelopes,
            "{kind:?}: envs {} !< {}",
            u.reconverge_envelopes,
            full.report.net.envelopes
        );
    }
}
