//! Cross-scheme agreement properties for the pluggable partition layer.
//!
//! The contract under test: a partition scheme is a *performance* knob —
//! block, edge-balanced, hash, and 2-D vertex-cut layouts must all
//! produce identical algorithm results (BFS levels, PageRank ranks up to
//! float tolerance, CC labels, SSSP distances) across locality counts
//! {1, 2, 4, 8} on random graphs, and the built shards must satisfy the
//! ghost-index invariants documented in `graph/partition.rs`.
//!
//! The base seed is overridable via `NWGRAPH_PROP_SEED` so CI can run a
//! seed matrix (distinct seeds generate distinct graphs and therefore
//! distinct cut/mirror/scatter schedules).

use nwgraph_hpx::algorithms::{bfs, cc, pagerank, pagerank::PrParams, sssp};
use nwgraph_hpx::amt::{NetConfig, SimConfig};
use nwgraph_hpx::graph::{generators, DistGraph, PartitionKind, PartitionScheme};
use nwgraph_hpx::testing::{forall, gen, PropConfig};

fn det() -> SimConfig {
    SimConfig::deterministic(NetConfig::default())
}

fn cfg(cases: u32) -> PropConfig {
    // NWGRAPH_PROP_SEED (CI seed matrix) and NWGRAPH_PROP_CASES (fast
    // local runs) override the defaults.
    PropConfig::from_env(cases, 0x9A57_17, 48)
}

const LOCALITIES: [u32; 4] = [1, 2, 4, 8];

#[test]
fn prop_scheme_invariants_hold_on_built_graphs() {
    // Ghost-index invariants: masters partition the vertices, ghost slots
    // route to masters with the master's dense index, locally homed edges
    // partition the edge set, and the quality metrics are >= 1.
    forall(
        &cfg(32),
        |rng, size| (gen::ugraph(rng, size), gen::locality_count(rng, size)),
        |(g, p)| {
            for kind in PartitionKind::all() {
                let scheme = kind.build(g, *p);
                let dist = DistGraph::build_with(g, scheme.clone());
                let mut edge_total = 0usize;
                let mut owned_total = 0usize;
                for s in &dist.shards {
                    edge_total += s.m_out();
                    owned_total += s.n_local();
                    for (i, &v) in s.owned_ids.iter().enumerate() {
                        if scheme.owner(v) != s.locality || scheme.master_index(v) != i {
                            return Err(format!("{kind:?}: owned table broken at {v}"));
                        }
                    }
                    for gi in 0..s.n_ghosts() {
                        let v = s.ghost_global_ids[gi];
                        if s.ghost_owner[gi] != scheme.owner(v)
                            || s.ghost_master_index[gi] as usize != scheme.master_index(v)
                            || s.ghost_owner[gi] == s.locality
                        {
                            return Err(format!("{kind:?}: ghost table broken at {v}"));
                        }
                    }
                }
                if edge_total != g.m() || owned_total != g.n() {
                    return Err(format!("{kind:?}: cover broken"));
                }
                let st = dist.partition_stats();
                if st.vertex_imbalance < 1.0 - 1e-9
                    || st.edge_imbalance < 1.0 - 1e-9
                    || st.replication_factor < 1.0 - 1e-9
                {
                    return Err(format!("{kind:?}: stats below 1.0: {st:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bfs_levels_identical_across_schemes() {
    forall(
        &cfg(32),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let root = rng.below(g.n() as u64) as u32;
            (g, root)
        },
        |(g, root)| {
            let want = bfs::sequential::distances(g, *root);
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    let res = bfs::run_async(&dist, *root, det());
                    bfs::validate_parents(g, *root, &res.parents)?;
                    if bfs::tree_levels(*root, &res.parents) != want {
                        return Err(format!("{kind:?} p={p}: BFS levels diverge"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pagerank_ranks_identical_across_schemes() {
    let params = PrParams { alpha: 0.85, iterations: 10 };
    forall(
        &cfg(32),
        |rng, size| gen::digraph(rng, size),
        |g| {
            let want = pagerank::sequential::pagerank(g, params);
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    let res = pagerank::run_async(
                        &dist,
                        params,
                        nwgraph_hpx::amt::FlushPolicy::Adaptive,
                        det(),
                    );
                    let diff = pagerank::max_abs_diff(&res.ranks, &want);
                    if diff > 1e-4 {
                        return Err(format!("{kind:?} p={p}: PageRank diff {diff}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cc_labels_identical_across_schemes() {
    forall(
        &cfg(32),
        |rng, size| gen::ugraph(rng, size),
        |g| {
            let want = cc::union_find(g);
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    let res = cc::run(&dist, det());
                    if res.labels != want {
                        return Err(format!("{kind:?} p={p}: CC labels diverge"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sssp_distances_identical_across_schemes() {
    forall(
        &cfg(32),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let gw = generators::with_random_weights(&g, 0.5, 9.5, rng.next_u64());
            let root = rng.below(gw.n() as u64) as u32;
            (gw, root)
        },
        |(gw, root)| {
            let want = sssp::dijkstra(gw, *root);
            let close = |dist: &[f32]| {
                dist.iter().zip(&want).all(|(a, b)| {
                    (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3
                })
            };
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(gw, kind.build(gw, p));
                    let a = sssp::run_async(gw, &dist, *root, det());
                    if !close(&a.dist) {
                        return Err(format!("{kind:?} p={p}: async SSSP diverges"));
                    }
                    let b = sssp::run_bsp(gw, &dist, *root, det());
                    if !close(&b.dist) {
                        return Err(format!("{kind:?} p={p}: bsp SSSP diverges"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_vertex_cut_never_loses_to_block_on_edge_balance() {
    // The reason the layer exists: across random graphs and locality
    // counts, the load-capped greedy cut's edge imbalance never exceeds
    // the block layout's by more than float noise.
    forall(
        &cfg(32),
        |rng, size| (gen::ugraph(rng, size + 8), gen::locality_count(rng, size)),
        |(g, p)| {
            let blk = DistGraph::build_with(g, PartitionKind::Block.build(g, *p));
            let vc = DistGraph::build_with(g, PartitionKind::VertexCut.build(g, *p));
            let (bi, vi) =
                (blk.partition_stats().edge_imbalance, vc.partition_stats().edge_imbalance);
            // The cap bounds the cut near the mean even when block is
            // badly skewed; tiny graphs can tie.
            if vi > bi + 1.0 + 1e-9 && g.m() > 8 * *p as usize {
                return Err(format!("vertex cut {vi} much worse than block {bi}"));
            }
            Ok(())
        },
    );
}
