//! Query-serving property suite.
//!
//! The contract under test: the serve layer's three amortizations —
//! landmark oracle, hot-source LRU cache, batched multi-source waves —
//! are pure *latency* knobs. Every answered distance must equal the
//! sequential Dijkstra oracle, every recovered path must be an edge-valid
//! walk whose weight sum equals the reported distance, and toggling the
//! oracle or cache may move hits and wave counts but never change an
//! answer (covered-vs-uncovered parity). Properties sweep all 4 partition
//! schemes × {1, 2, 4, 8} localities × random flush policies; the
//! benchmark pin runs the acceptance shape (kron10 @ 8 localities, 1000
//! queries) on both the simulator and the threaded runtime, including the
//! vertex-cut regression (serve never calls `require_mirror_free`, so a
//! mirrored cut must work).
//!
//! Environment knobs (see `testing::PropConfig::from_env`):
//! `NWGRAPH_PROP_SEED` pins the base seed (the CI seed matrix);
//! `NWGRAPH_PROP_CASES` shrinks case counts for fast local runs.

use nwgraph_hpx::amt::{FlushPolicy, NetConfig, RuntimeKind, SimConfig};
use nwgraph_hpx::graph::generators::{self, SplitMix64};
use nwgraph_hpx::graph::{Csr, DistGraph, PartitionKind};
use nwgraph_hpx::serve::{self, Answer, ServeParams};
use nwgraph_hpx::testing::{forall, gen, PropConfig};

fn det() -> SimConfig {
    SimConfig::deterministic(NetConfig::default())
}

fn cfg(cases: u32) -> PropConfig {
    PropConfig::from_env(cases, 0x5E27E5, 40)
}

const LOCALITIES: [u32; 4] = [1, 2, 4, 8];

/// Wall-clock latency pins (`p50 > 0`, `qps > 0`) are only meaningful
/// where the clock is trustworthy: a fast machine can serve a cached
/// query inside one timer tick and legitimately measure 0us. The suite
/// always checks the counter-based invariants; set
/// `NWGRAPH_STRICT_TIMING=1` (the serve-props CI job does) to also
/// enforce the strictly-positive latency pins.
fn strict_timing() -> bool {
    std::env::var("NWGRAPH_STRICT_TIMING").map(|v| v == "1").unwrap_or(false)
}

/// Same policy corners as the engine suite: the serving waves must answer
/// correctly whatever flush policy drives the aggregator underneath.
fn gen_policy(rng: &mut SplitMix64) -> FlushPolicy {
    match rng.below(7) {
        0 => FlushPolicy::Unbatched,
        1 => FlushPolicy::Items(1 + rng.below(64) as usize),
        2 => FlushPolicy::Bytes(8 + rng.below(1024) as usize),
        3 => FlushPolicy::Adaptive,
        4 => FlushPolicy::TimeWindow(rng.below(30)),
        5 => FlushPolicy::LatencyAdaptive,
        _ => FlushPolicy::Manual,
    }
}

/// Random undirected graph with the pair-keyed symmetric metric the
/// oracle's triangle bounds require.
fn gen_metric_graph(rng: &mut SplitMix64, size: usize) -> Csr {
    let g = gen::ugraph(rng, size);
    generators::with_symmetric_random_weights(&g, 0.5, 9.5, rng.next_u64())
}

fn small_params(seed: u64) -> ServeParams {
    ServeParams { queries: 48, landmarks: 4, cache: 8, batch: 4, oracle: true, seed }
}

#[test]
fn prop_serve_answers_match_dijkstra_on_every_scheme() {
    forall(
        &cfg(6),
        |rng, size| {
            let gw = gen_metric_graph(rng, size);
            // Occasionally route the waves through the threaded runtime —
            // answers must not depend on the substrate either.
            let rt =
                if rng.below(4) == 0 { RuntimeKind::Threads } else { RuntimeKind::Sim };
            (gw, gen_policy(rng), rng.next_u64(), rt)
        },
        |(gw, policy, seed, rt)| {
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(gw, kind.build(gw, p));
                    let scfg = SimConfig { runtime: *rt, ..det() };
                    let res = serve::run(gw, &dist, &small_params(*seed), *policy, scfg);
                    serve::validate(gw, &res.queries, &res.answers).map_err(|e| {
                        format!("{kind:?} p={p} {policy:?} {rt:?}: {e}")
                    })?;
                    let q = res.report.query;
                    if q.queries != 48 || q.waves >= q.queries {
                        return Err(format!("{kind:?} p={p}: no batching win: {q:?}"));
                    }
                    // Ordering and sign are clock-independent invariants;
                    // strictly-positive pins are opt-in (see strict_timing).
                    if q.qps < 0.0 || q.p50_us < 0.0 || q.p99_us < q.p50_us {
                        return Err(format!("{kind:?} p={p}: bad latency stats: {q:?}"));
                    }
                    if strict_timing() && (q.qps <= 0.0 || q.p50_us <= 0.0) {
                        return Err(format!("{kind:?} p={p}: zero latency stats: {q:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Answers may differ only within the float envelope the engines already
/// promise (paths may legitimately route differently between equally
/// short walks; `serve::validate` checks the walks themselves).
fn answers_close(a: &Answer, b: &Answer) -> bool {
    let close_f = |x: f32, y: f32| {
        (x.is_infinite() && y.is_infinite()) || (x - y).abs() < 1e-3
    };
    match (a, b) {
        (Answer::Distance(x), Answer::Distance(y)) => close_f(*x, *y),
        (Answer::Path { dist: x, path: px }, Answer::Path { dist: y, path: py }) => {
            close_f(*x, *y) && px.is_some() == py.is_some()
        }
        (Answer::Rank(x), Answer::Rank(y)) => x.abs_diff(*y) <= 2,
        _ => false,
    }
}

#[test]
fn prop_oracle_and_cache_hits_never_change_answers() {
    forall(
        &cfg(8),
        |rng, size| {
            let gw = gen_metric_graph(rng, size);
            let kind = PartitionKind::all()[rng.below(4) as usize];
            let p = LOCALITIES[rng.below(4) as usize];
            (gw, kind, p, gen_policy(rng), rng.next_u64())
        },
        |(gw, kind, p, policy, seed)| {
            let dist = DistGraph::build_with(gw, kind.build(gw, *p));
            let base = small_params(*seed);
            let reference = serve::run(gw, &dist, &base, *policy, det());
            serve::validate(gw, &reference.queries, &reference.answers)
                .map_err(|e| format!("reference {kind:?} p={p}: {e}"))?;
            for variant in [
                ServeParams { oracle: false, ..base.clone() },
                ServeParams { cache: 0, ..base.clone() },
                ServeParams { oracle: false, cache: 0, batch: 1, ..base.clone() },
                ServeParams { landmarks: 1, cache: 2, ..base.clone() },
            ] {
                let res = serve::run(gw, &dist, &variant, *policy, det());
                if res.queries != reference.queries {
                    return Err(format!("{kind:?} p={p}: query streams diverge"));
                }
                for (i, (a, b)) in
                    reference.answers.iter().zip(&res.answers).enumerate()
                {
                    if !answers_close(a, b) {
                        return Err(format!(
                            "{kind:?} p={p} {policy:?} query {i} {:?}: {a:?} vs {b:?} \
                             under {variant:?}",
                            res.queries[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn serve_acceptance_on_benchmark_kron10() {
    // The PR acceptance pin: 1000 queries on kron10 @ 8 localities answer
    // correctly on both substrates with real covered traffic (oracle +
    // cache hits > 0), a batching win (waves < queries), and a
    // well-ordered wall-clock latency distribution (strictly positive
    // under NWGRAPH_STRICT_TIMING=1) — on block *and* on a vertex cut
    // that really mirrors (the regression for inheriting
    // `require_mirror_free`, which serve must never call).
    let seed = cfg(1).seed; // honors NWGRAPH_PROP_SEED via from_env
    let g = generators::kron(10, 8, seed);
    let gw = generators::with_symmetric_random_weights(&g, 1.0, 10.0, seed + 1);
    let params = ServeParams {
        queries: 1000,
        landmarks: 8,
        cache: 32,
        batch: 16,
        oracle: true,
        seed: seed + 2,
    };
    for kind in [PartitionKind::Block, PartitionKind::VertexCut] {
        let dist = DistGraph::build_with(&gw, kind.build(&gw, 8));
        if kind == PartitionKind::VertexCut {
            assert!(dist.has_mirrors(), "kron10@8 vertex cut should mirror");
        }
        for rt in [RuntimeKind::Sim, RuntimeKind::Threads] {
            let scfg = SimConfig { runtime: rt, ..det() };
            let res = serve::run(&gw, &dist, &params, FlushPolicy::Adaptive, scfg);
            serve::validate(&gw, &res.queries, &res.answers)
                .unwrap_or_else(|e| panic!("{kind:?} {rt:?}: {e}"));
            let q = res.report.query;
            assert_eq!(q.queries, 1000, "{kind:?} {rt:?}");
            assert!(q.oracle_hits + q.cache_hits > 0, "{kind:?} {rt:?}: no hits: {q:?}");
            assert!(q.cache_hits > 0, "{kind:?} {rt:?}: hot pool never hit: {q:?}");
            assert!(q.waves > 0 && q.waves < q.queries, "{kind:?} {rt:?}: {q:?}");
            assert!(
                q.qps >= 0.0 && q.p50_us >= 0.0 && q.p99_us >= q.p50_us,
                "{kind:?} {rt:?}: {q:?}"
            );
            assert!(res.report.wall_us >= 0.0, "{kind:?} {rt:?}");
            if strict_timing() {
                assert!(
                    q.qps > 0.0 && q.p50_us > 0.0 && res.report.wall_us > 0.0,
                    "{kind:?} {rt:?}: zero wall-clock stats: {q:?}"
                );
            }
        }
    }
}

#[test]
fn serve_report_merges_wave_traffic() {
    // The serve report is the *sum* of its engine runs: a run with waves
    // must show aggregator and interconnect traffic, and the query block
    // must be stamped exactly once (queries == stream length).
    let seed = cfg(1).seed;
    let g = generators::kron(8, 6, seed);
    let gw = generators::with_symmetric_random_weights(&g, 1.0, 10.0, seed + 1);
    let dist = DistGraph::block(&gw, 4);
    let res = serve::run(&gw, &dist, &small_params(seed + 2), FlushPolicy::Adaptive, det());
    serve::validate(&gw, &res.queries, &res.answers).unwrap();
    let r = &res.report;
    assert!(r.query.waves > 0);
    assert!(r.net.messages > 0, "waves produced no interconnect traffic");
    assert!(r.events > 0);
    assert!(r.makespan_us > 0.0);
    assert_eq!(r.busy_us.len(), 4);
    assert_eq!(r.query.queries as usize, res.queries.len());
    assert!(r.partition.replication_factor >= 1.0);
}
