//! Property tests for the delta-stepping SSSP engine and its cross-model
//! oracle harness.
//!
//! * **Oracle agreement** — delta-stepping equals the Dijkstra oracle
//!   across 64 random weighted graphs × {1, 2, 4, 8} localities × a random
//!   [`FlushPolicy`] × a random Δ (spanning Dijkstra-like through
//!   Bellman-Ford).
//! * **Work efficiency** — on weighted RMAT inputs a bucket-ordered
//!   schedule performs no more relaxations than the chaotic asynchronous
//!   label-correcting engine, and strictly fewer on the benchmark-scale
//!   RMAT graph at 8 localities.
//! * **Δ = ∞ ≡ Bellman-Ford** — with a single bucket the delta engine's
//!   round-synchronous schedule reproduces the BSP engine exactly:
//!   identical distances, relaxation totals, aggregator envelope counts,
//!   and barrier (superstep) counts.
//! * **Edge cases** — zero-weight edges (including cycles), disconnected
//!   components, single-vertex graphs, sources on non-zero localities,
//!   and duplicate parallel edges.
//!
//! The base seed is overridable via `NWGRAPH_PROP_SEED` so CI can run a
//! deterministic seed matrix (bucket-coordination schedules depend on the
//! generated graphs, so distinct seeds exercise distinct coordination
//! interleavings reproducibly).

use nwgraph_hpx::algorithms::sssp;
use nwgraph_hpx::amt::{FlushPolicy, NetConfig, SimConfig};
use nwgraph_hpx::graph::generators::SplitMix64;
use nwgraph_hpx::graph::{generators, Csr, DistGraph, EdgeList};
use nwgraph_hpx::testing::{forall, gen, PropConfig};

fn det() -> SimConfig {
    SimConfig::deterministic(NetConfig::default())
}

fn cfg(cases: u32) -> PropConfig {
    // NWGRAPH_PROP_CASES additionally shrinks case counts for fast local
    // runs; the seed matrix still comes through NWGRAPH_PROP_SEED.
    PropConfig::from_env(cases, 0xDE17A5, 48)
}

/// Base seed for the non-`forall` benchmark pins; honors
/// `NWGRAPH_PROP_SEED` through the same [`PropConfig::from_env`] path.
fn prop_seed() -> u64 {
    cfg(1).seed
}

/// Draw a flush policy uniformly from the interesting corners of the
/// policy space.
fn gen_policy(rng: &mut SplitMix64) -> FlushPolicy {
    match rng.below(5) {
        0 => FlushPolicy::Unbatched,
        1 => FlushPolicy::Items(1 + rng.below(64) as usize),
        2 => FlushPolicy::Bytes(8 + rng.below(1024) as usize),
        3 => FlushPolicy::Adaptive,
        _ => FlushPolicy::Manual,
    }
}

/// Draw a Δ spanning the whole spectrum: far below the minimum weight
/// (Dijkstra-like bucket ordering), around the weight scale, far above it
/// (few buckets), and ∞ (Bellman-Ford).
fn gen_delta(rng: &mut SplitMix64) -> f32 {
    match rng.below(6) {
        0 => 0.05,
        1 => 0.6,
        2 => 1.5,
        3 => 5.0,
        4 => 20.0,
        _ => f32::INFINITY,
    }
}

fn check_against(want: &[f32], got: &[f32], tag: &str) -> Result<(), String> {
    for (v, (a, b)) in got.iter().zip(want).enumerate() {
        let ok = (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3;
        if !ok {
            return Err(format!("{tag}: dist[{v}] = {a}, oracle {b}"));
        }
    }
    Ok(())
}

#[test]
fn prop_delta_stepping_matches_dijkstra_oracle() {
    forall(
        &cfg(64),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let gw = generators::with_random_weights(&g, 0.5, 9.5, rng.next_u64());
            let root = rng.below(gw.n() as u64) as u32;
            (gw, root, gen_policy(rng), gen_delta(rng))
        },
        |(gw, root, policy, delta)| {
            let want = sssp::dijkstra(gw, *root);
            for p in [1u32, 2, 4, 8] {
                let dist = DistGraph::block(gw, p);
                let res = sssp::run_delta_with(gw, &dist, *root, *delta, *policy, det());
                check_against(&want, &res.dist, &format!("p={p} delta={delta} {policy:?}"))?;
                // Combiner conservation: at quiescence every accumulated
                // relaxation was either folded away or shipped.
                let agg = res.report.agg;
                if agg.items != agg.folded + agg.sent_items {
                    return Err(format!("p={p}: aggregation leak: {agg:?}"));
                }
            }
            Ok(())
        },
    );
}

/// Δ that makes every GAP-style weight (`w >= 1`) heavy, which lower-bounds
/// the schedule: each settled vertex relaxes its edges exactly once
/// (bucket-ordered Dijkstra). [`sssp::auto_delta`] lands there on RMAT
/// degree distributions; the cap keeps the invariant independent of the
/// dedup-dependent mean degree of small RMAT instances.
fn tuned_delta(gw: &Csr) -> f32 {
    sssp::auto_delta(gw).min(0.9)
}

#[test]
fn prop_delta_work_efficiency_le_async_on_rmat() {
    // Bucket ordering never performs more relaxations than chaotic
    // label-correcting on weighted RMAT inputs, for any locality count
    // and any flush policy.
    forall(
        &cfg(16),
        |rng, size| {
            let scale = 5 + (size as u32 % 4); // kron5..kron8
            let g = generators::kron(scale, 8, rng.next_u64());
            let gw = generators::with_random_weights(&g, 1.0, 10.0, rng.next_u64());
            let p = 1u32 << rng.below(4);
            (gw, p, gen_policy(rng))
        },
        |(gw, p, policy)| {
            let dist = DistGraph::block(gw, *p);
            let d = sssp::run_delta_with(gw, &dist, 0, tuned_delta(gw), *policy, det());
            let a = sssp::run_async(gw, &dist, 0, det());
            check_against(&a.dist, &d.dist, "delta vs async")?;
            let (dr, ar) = (d.report.work.relaxations, a.report.work.relaxations);
            if dr > ar {
                return Err(format!("delta did more work: {dr} > {ar} relaxations"));
            }
            Ok(())
        },
    );
}

#[test]
fn delta_strictly_beats_async_on_benchmark_rmat() {
    // Acceptance criterion: on the benchmark-scale weighted RMAT graph at
    // 8 localities, bucket ordering performs strictly fewer relaxations
    // than asynchronous label-correcting (which re-relaxes vertices as
    // stale cross-locality proposals land).
    let g = generators::kron(10, 8, prop_seed());
    let gw = generators::with_random_weights(&g, 1.0, 10.0, prop_seed() + 1);
    let dist = DistGraph::block(&gw, 8);
    let d = sssp::run_delta_with(&gw, &dist, 0, tuned_delta(&gw), FlushPolicy::Adaptive, det());
    let a = sssp::run_async(&gw, &dist, 0, det());
    check_against(&a.dist, &d.dist, "delta vs async").unwrap();
    assert!(
        d.report.work.relaxations < a.report.work.relaxations,
        "delta {} vs async {} relaxations",
        d.report.work.relaxations,
        a.report.work.relaxations
    );
    assert!(d.report.work.useful_relaxations <= d.report.work.relaxations);
}

#[test]
fn prop_delta_inf_matches_bsp_bellman_ford_counts() {
    // With Δ = ∞ every edge is light and there is a single bucket: the
    // delta engine's round-synchronous light loop IS the BSP Bellman-Ford
    // superstep schedule. Distances, relaxation totals, and aggregator
    // envelope accounting must match exactly; barrier counts match up to
    // the engines' terminal handshakes (see below).
    forall(
        &cfg(12),
        |rng, size| {
            let g = gen::ugraph(rng, size + 4);
            let gw = generators::with_random_weights(&g, 0.5, 9.5, rng.next_u64());
            let p = 1u32 << rng.below(4);
            (gw, p)
        },
        |(gw, p)| {
            // A degree-0 source terminates the two engines one no-op round
            // apart; pick a root that actually relaxes something.
            let root = match (0..gw.n() as u32).find(|&v| gw.degree(v) > 0) {
                Some(v) => v,
                None => return Ok(()), // edgeless graph: nothing to compare
            };
            let dist = DistGraph::block(gw, *p);
            let d =
                sssp::run_delta_with(gw, &dist, root, f32::INFINITY, FlushPolicy::Manual, det());
            let b = sssp::run_bsp(gw, &dist, root, det());
            if d.dist != b.dist {
                return Err("distances differ".into());
            }
            let (dw, bw) = (d.report.work, b.report.work);
            if dw.relaxations != bw.relaxations {
                return Err(format!("relaxations {} vs {}", dw.relaxations, bw.relaxations));
            }
            let (da, ba) = (d.report.agg, b.report.agg);
            if (da.items, da.folded, da.sent_items, da.envelopes)
                != (ba.items, ba.folded, ba.sent_items, ba.envelopes)
            {
                return Err(format!("aggregation differs: {da:?} vs {ba:?}"));
            }
            // Superstep parity up to the terminal handshake: both engines
            // run the same K relaxing rounds (2 barriers each). Delta
            // always appends one no-op heavy round before terminating;
            // BSP skips its trailing empty round only when the last
            // relaxing round produced no remote sends (always at p=1,
            // where stale local proposals don't count as activity).
            let (db, bb) = (d.report.barriers, b.report.barriers);
            if db != bb && db != bb + 2 {
                return Err(format!("supersteps differ: {db} vs {bb} barriers"));
            }
            Ok(())
        },
    );
}

/// Run every distributed engine (delta at several Δ) against the oracle.
fn all_engines_match(gw: &Csr, root: u32, ps: &[u32]) {
    let want = sssp::dijkstra(gw, root);
    for &p in ps {
        let dist = DistGraph::block(gw, p);
        check_against(&want, &sssp::run_async(gw, &dist, root, det()).dist, "async").unwrap();
        check_against(&want, &sssp::run_bsp(gw, &dist, root, det()).dist, "bsp").unwrap();
        for delta in [0.1f32, 2.0, f32::INFINITY] {
            let res =
                sssp::run_delta_with(gw, &dist, root, delta, FlushPolicy::Adaptive, det());
            check_against(&want, &res.dist, &format!("delta={delta} p={p}")).unwrap();
        }
    }
}

#[test]
fn zero_weight_edges_including_cycles() {
    // 0 -0.0- 1 -2.0- 2 -0.0- 3, plus a zero-weight cycle 4-5-6 hanging
    // off vertex 2 by a unit edge. Zero-weight light edges must propagate
    // (equal distances) without re-relaxation loops.
    let mut el = EdgeList::new(7);
    let mut add = |u: u32, v: u32, w: f32| {
        el.push_weighted(u, v, w);
        el.push_weighted(v, u, w);
    };
    add(0, 1, 0.0);
    add(1, 2, 2.0);
    add(2, 3, 0.0);
    add(2, 4, 1.0);
    add(4, 5, 0.0);
    add(5, 6, 0.0);
    add(6, 4, 0.0);
    let gw = Csr::from_edge_list(&el);
    let want = sssp::dijkstra(&gw, 0);
    assert_eq!(want, vec![0.0, 0.0, 2.0, 2.0, 3.0, 3.0, 3.0]);
    all_engines_match(&gw, 0, &[1, 2, 4]);
}

#[test]
fn disconnected_components_keep_infinity() {
    // Two weighted triangles with no bridge: the far component must stay
    // at INFINITY under every engine and every partitioning.
    let mut el = EdgeList::new(6);
    for (u, v) in [(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)] {
        el.push_weighted(u, v, 1.5);
        el.push_weighted(v, u, 1.5);
    }
    let gw = Csr::from_edge_list(&el);
    let want = sssp::dijkstra(&gw, 0);
    assert!(want[3..].iter().all(|d| d.is_infinite()));
    all_engines_match(&gw, 0, &[1, 2, 3, 4]);
    // From the other component, the roles flip.
    all_engines_match(&gw, 4, &[1, 2, 3, 4]);
}

#[test]
fn single_vertex_graph() {
    let gw = Csr::from_edge_list(&EdgeList::new(1));
    for p in [1u32, 2, 4, 8] {
        let dist = DistGraph::block(&gw, p);
        for res in [
            sssp::run_async(&gw, &dist, 0, det()),
            sssp::run_bsp(&gw, &dist, 0, det()),
            sssp::run_delta_with(&gw, &dist, 0, 1.0, FlushPolicy::Manual, det()),
        ] {
            assert_eq!(res.dist, vec![0.0], "p={p}");
        }
    }
}

#[test]
fn source_on_nonzero_locality() {
    // Root owned by the last of 4 localities; distances must be exact and
    // the far end reachable across every boundary.
    let gw = generators::with_random_weights(&generators::path(9), 1.0, 1.0 + 1e-6, 7);
    all_engines_match(&gw, 8, &[4]);
    let want = sssp::dijkstra(&gw, 8);
    assert!((want[0] - 8.0).abs() < 1e-3);
    // And a random weighted graph rooted away from locality 0.
    let g = generators::urand(6, 4, 11);
    let gw = generators::with_random_weights(&g, 1.0, 10.0, 12);
    all_engines_match(&gw, (gw.n() - 1) as u32, &[2, 4, 8]);
}

#[test]
fn duplicate_parallel_edges_take_the_min() {
    // Two parallel 0->1 edges with different weights (and a heavy/light
    // split that separates them when delta = 2): the cheaper one must win
    // in every engine.
    let mut el = EdgeList::new(3);
    el.push_weighted(0, 1, 5.0);
    el.push_weighted(0, 1, 1.0);
    el.push_weighted(1, 2, 2.0);
    let gw = Csr::from_edge_list(&el);
    assert_eq!(gw.m(), 3, "parallel edges must survive CSR construction");
    let want = sssp::dijkstra(&gw, 0);
    assert_eq!(want, vec![0.0, 1.0, 3.0]);
    for p in [1u32, 2, 3] {
        let dist = DistGraph::block(&gw, p);
        check_against(&want, &sssp::run_async(&gw, &dist, 0, det()).dist, "async").unwrap();
        check_against(&want, &sssp::run_bsp(&gw, &dist, 0, det()).dist, "bsp").unwrap();
        for delta in [0.5f32, 2.0, f32::INFINITY] {
            let res = sssp::run_delta_with(&gw, &dist, 0, delta, FlushPolicy::Unbatched, det());
            check_against(&want, &res.dist, &format!("delta={delta}")).unwrap();
        }
    }
}
