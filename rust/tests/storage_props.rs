//! Storage & ingestion property suite.
//!
//! The contract under test: shard adjacency *storage* (plain arrays vs
//! delta-varint compressed rows) and *ingestion mode* (materialized CSR
//! vs one-pass streaming) are pure memory knobs. Every row must iterate
//! identically under both encodings, a streamed build must be deeply
//! equal to the materialized build of the same generator, and BFS /
//! PageRank / SSSP / CC answers must be invariant to both axes — across
//! all 4 partition schemes × {1, 2, 4, 8} localities. The scale pins at
//! the bottom hold the PR acceptance line: compressed storage at ≤ 60%
//! of plain bytes/edge on kron14, and a kron16 streamed-compressed BFS
//! end-to-end whose builder peak undercuts the materialized path.
//!
//! Environment knobs (see `testing::PropConfig::from_env`):
//! `NWGRAPH_PROP_SEED` pins the base seed (the CI seed matrix);
//! `NWGRAPH_PROP_CASES` shrinks case counts for fast local runs.

use nwgraph_hpx::algorithms::{bfs, cc, pagerank, pagerank::PrParams, sssp};
use nwgraph_hpx::amt::{NetConfig, SimConfig};
use nwgraph_hpx::graph::generators::{self, SplitMix64};
use nwgraph_hpx::graph::stream::{build_streamed, WeightSpec};
use nwgraph_hpx::graph::{Csr, DistGraph, EdgeSource, PartitionKind, StorageKind, VertexId};
use nwgraph_hpx::testing::{forall, gen, PropConfig};

fn det() -> SimConfig {
    SimConfig::deterministic(NetConfig::default())
}

fn cfg(cases: u32) -> PropConfig {
    PropConfig::from_env(cases, 0x57054A6E, 40)
}

const LOCALITIES: [u32; 4] = [1, 2, 4, 8];

fn build(g: &Csr, kind: PartitionKind, p: u32, storage: StorageKind) -> DistGraph {
    DistGraph::build_with_storage(g, kind.build(g, p), storage)
}

/// Row-by-row iteration equality between two builds of the same graph
/// that differ only in storage encoding. Covers every read path the
/// engines use: local-row iteration, global out-neighbors (sorted, so
/// intersection via binary search stays valid), weighted edge pairs,
/// and the in-adjacency.
fn assert_rows_equal(plain: &DistGraph, comp: &DistGraph, ctx: &str) -> Result<(), String> {
    if plain.n() != comp.n() || plain.m() != comp.m() {
        return Err(format!("{ctx}: n/m diverge"));
    }
    let mut pv: Vec<VertexId> = Vec::new();
    let mut cv: Vec<VertexId> = Vec::new();
    for (sp, sc) in plain.shards.iter().zip(&comp.shards) {
        if sp.n_rows() != sc.n_rows() || sp.n_local() != sc.n_local() {
            return Err(format!("{ctx}: shard {} row counts diverge", sp.locality));
        }
        for row in 0..sp.n_rows() {
            if sp.row_len(row) != sc.row_len(row) {
                return Err(format!("{ctx}: shard {} row {row} len", sp.locality));
            }
            let lp: Vec<u32> = sp.row_locals(row).collect();
            let lc: Vec<u32> = sc.row_locals(row).collect();
            if lp != lc {
                return Err(format!("{ctx}: shard {} row {row} locals", sp.locality));
            }
            let ep: Vec<(u32, f32)> = sp.row_edges(row).collect();
            let ec: Vec<(u32, f32)> = sc.row_edges(row).collect();
            if ep != ec {
                return Err(format!("{ctx}: shard {} row {row} edges", sp.locality));
            }
        }
        for u in 0..sp.n_local() {
            let np = sp.out_neighbors_into(u, &mut pv);
            if !np.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("{ctx}: shard {} local {u} not ascending", sp.locality));
            }
            let np = np.to_vec();
            if np != sc.out_neighbors_into(u, &mut cv) {
                return Err(format!("{ctx}: shard {} local {u} out", sp.locality));
            }
            if sp.in_len(u) != sc.in_len(u)
                || !sp.in_neighbors_iter(u).eq(sc.in_neighbors_iter(u))
            {
                return Err(format!("{ctx}: shard {} local {u} in", sp.locality));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_compressed_rows_iterate_identically_to_plain() {
    forall(
        &cfg(10),
        |rng, size| {
            // Alternate weighted/unweighted so both compressed layouts
            // (with and without entry offsets) are exercised.
            let g = gen::ugraph(rng, size);
            if rng.below(2) == 0 {
                generators::with_symmetric_random_weights(&g, 0.5, 9.5, rng.next_u64())
            } else {
                g
            }
        },
        |g| {
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let plain = build(g, kind, p, StorageKind::Plain);
                    let comp = build(g, kind, p, StorageKind::Compressed);
                    assert_rows_equal(&plain, &comp, &format!("{kind:?} p={p}"))?;
                }
            }
            Ok(())
        },
    );
}

/// Random generator parameters a stream can replay (arbitrary `Csr`s
/// cannot be streamed — only generator and file sources can).
fn gen_source(rng: &mut SplitMix64, size: usize) -> (String, u32, usize, u64) {
    let name = ["urand", "urand-directed", "kron"][rng.below(3) as usize];
    let scale = 4 + rng.below(1 + (size as u64 / 14).min(3)) as u32; // 4..=7
    let degree = 1 + rng.below(5) as usize;
    (name.to_string(), scale, degree, rng.next_u64())
}

fn materialize(name: &str, scale: u32, degree: usize, seed: u64) -> Csr {
    match name {
        "urand" => generators::urand(scale, degree, seed),
        "urand-directed" => generators::urand_directed(scale, degree, seed),
        _ => generators::kron(scale, degree, seed),
    }
}

#[test]
fn prop_streamed_build_equals_materialized() {
    forall(
        &cfg(12),
        |rng, size| {
            let (name, scale, degree, seed) = gen_source(rng, size);
            let kind = PartitionKind::all()[rng.below(4) as usize];
            let p = LOCALITIES[rng.below(4) as usize];
            let storage =
                [StorageKind::Plain, StorageKind::Compressed][rng.below(2) as usize];
            (name, scale, degree, seed, kind, p, storage)
        },
        |(name, scale, degree, seed, kind, p, storage)| {
            let g = materialize(name, *scale, *degree, *seed);
            let src = EdgeSource::from_generator(name, *scale, *degree, *seed)
                .map_err(|e| e.to_string())?;
            let want = build(&g, *kind, *p, *storage);
            let got = build_streamed(&src, *kind, *p, *storage, None)
                .map_err(|e| e.to_string())?;
            if got.n() != want.n() || got.m() != want.m() {
                return Err(format!("{name} {kind:?} p={p}: n/m diverge"));
            }
            for (sg, sw) in got.shards.iter().zip(&want.shards) {
                if sg != sw {
                    return Err(format!(
                        "{name} {kind:?} p={p} {storage:?}: shard {} diverges",
                        sg.locality
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_answers_invariant_to_storage_and_ingest() {
    // The four builds of the same kron population — {plain, compressed}
    // × {materialized, streamed} — must produce identical BFS parents,
    // PageRank ranks, CC labels, and SSSP distances: same shards, same
    // iteration order, same messages, same floats.
    let params = PrParams { alpha: 0.85, iterations: 8 };
    forall(
        &cfg(8),
        |rng, size| {
            let scale = 4 + rng.below(1 + (size as u64 / 14).min(3)) as u32;
            let degree = 2 + rng.below(4) as usize;
            let kind = PartitionKind::all()[rng.below(4) as usize];
            let p = LOCALITIES[rng.below(4) as usize];
            (scale, degree, rng.next_u64(), kind, p)
        },
        |&(scale, degree, seed, kind, p)| {
            let g = generators::kron(scale, degree, seed);
            let gw = generators::with_symmetric_random_weights(&g, 1.0, 10.0, seed + 1);
            let src = EdgeSource::kron(scale, degree, seed);
            let spec = WeightSpec { lo: 1.0, hi: 10.0, seed: seed + 1 };
            let root = (seed % g.n() as u64) as VertexId;

            let mut base: Option<(Vec<i64>, Vec<f32>, Vec<u32>, Vec<f32>)> = None;
            for storage in [StorageKind::Plain, StorageKind::Compressed] {
                for streamed in [false, true] {
                    let (dist, distw) = if streamed {
                        let d = build_streamed(&src, kind, p, storage, None)
                            .map_err(|e| e.to_string())?;
                        let dw = build_streamed(&src, kind, p, storage, Some(spec))
                            .map_err(|e| e.to_string())?;
                        (d, dw)
                    } else {
                        (build(&g, kind, p, storage), build(&gw, kind, p, storage))
                    };
                    let ctx = format!("{kind:?} p={p} {storage:?} streamed={streamed}");
                    let parents = bfs::run_async(&dist, root, det()).parents;
                    bfs::validate_parents(&g, root, &parents)
                        .map_err(|e| format!("{ctx}: {e}"))?;
                    let ranks = pagerank::run_bsp(&dist, params, det()).ranks;
                    let labels = cc::run(&dist, det()).labels;
                    let sd = sssp::run_delta_dist(&distw, root, det()).dist;
                    match &base {
                        None => base = Some((parents, ranks, labels, sd)),
                        Some((bp, br, bl, bs)) => {
                            if &parents != bp {
                                return Err(format!("{ctx}: BFS parents diverge"));
                            }
                            if &ranks != br {
                                return Err(format!("{ctx}: PageRank ranks diverge"));
                            }
                            if &labels != bl {
                                return Err(format!("{ctx}: CC labels diverge"));
                            }
                            for (v, (a, b)) in sd.iter().zip(bs).enumerate() {
                                let ok = (a.is_infinite() && b.is_infinite())
                                    || (a - b).abs() < 1e-6;
                                if !ok {
                                    return Err(format!("{ctx}: sssp[{v}]: {a} vs {b}"));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn compressed_kron14_is_at_most_60_percent_of_plain() {
    // The PR acceptance pin: at kron14 (real skewed degree distribution,
    // long sorted runs of small deltas) the delta-varint encoding must
    // reach ≤ 60% of plain bytes/edge — on block *and* on the mirrored
    // vertex cut, where the ghost tables dilute the adjacency share.
    let seed = cfg(1).seed;
    let g = generators::kron(14, 8, seed);
    for kind in [PartitionKind::Block, PartitionKind::VertexCut] {
        let plain = build(&g, kind, 4, StorageKind::Plain).mem_stats();
        let comp = build(&g, kind, 4, StorageKind::Compressed).mem_stats();
        assert_eq!(plain.storage, "plain");
        assert_eq!(comp.storage, "compressed");
        assert!(plain.bytes_per_edge > 0.0 && comp.bytes_per_edge > 0.0);
        let ratio = comp.bytes_per_edge / plain.bytes_per_edge;
        assert!(
            ratio <= 0.60,
            "{kind:?}: compressed/plain = {:.2}/{:.2} = {ratio:.3} > 0.60",
            comp.bytes_per_edge,
            plain.bytes_per_edge
        );
    }
}

#[test]
fn streamed_kron16_bfs_end_to_end() {
    // The memory-limit acceptance shape (ablation A9's largest default
    // cell): kron16 streamed straight into compressed shards at 8
    // localities — the whole-graph CSR is never on the distributed build
    // path — and async BFS answers match the sequential oracle. The
    // oracle CSR below is test-only scaffolding, built *after* the
    // streamed build so its peak cannot be confused with the builder's.
    let seed = cfg(1).seed;
    let src = EdgeSource::kron(16, 8, seed);
    let dist = build_streamed(&src, PartitionKind::Block, 8, StorageKind::Compressed, None)
        .expect("streamed kron16 build");
    let mem = dist.mem_stats();
    assert_eq!(mem.storage, "compressed");
    assert!(mem.total_shard_bytes > 0 && mem.peak_builder_bytes > 0);

    let g = generators::kron(16, 8, seed);
    assert_eq!(dist.n(), g.n());
    assert_eq!(dist.m(), g.m());
    let materialized = build(&g, PartitionKind::Block, 8, StorageKind::Compressed);
    assert!(
        mem.peak_builder_bytes < materialized.mem_stats().peak_builder_bytes,
        "streamed peak {} should undercut materialized leader peak {}",
        mem.peak_builder_bytes,
        materialized.mem_stats().peak_builder_bytes
    );

    let res = bfs::run_async(&dist, 0, det());
    bfs::validate_parents(&g, 0, &res.parents).expect("kron16 BFS parents");
    let want = bfs::sequential::bfs(&g, 0);
    for v in 0..g.n() {
        assert_eq!(
            res.parents[v] >= 0,
            want[v] >= 0,
            "kron16 reachability mismatch at {v}"
        );
    }
}
