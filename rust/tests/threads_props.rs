//! Threaded-runtime equivalence property suite.
//!
//! The contract under test: [`RuntimeKind`] is a pure *substrate* knob.
//! Running a `VertexProgram` on the discrete-event simulator (`sim`) or on
//! one OS thread per locality (`threads`) must produce the same answers —
//! BFS levels, SSSP distances, PageRank ranks, and CC labels — across all
//! four partition schemes, {1, 2, 4} localities, and random flush
//! policies. The integer-valued fixed points (BFS levels, CC labels) must
//! be *identical*; the float algorithms agree within the same tolerances
//! the engines already promise against their sequential oracles, because
//! real thread interleavings reorder float accumulation.
//!
//! Also here: a loom-free repeat-run stress test (determinism of *results*,
//! not schedules) and the PR acceptance pin — on kron10@8 every algorithm
//! is oracle-identical across runtimes while the threaded `SimReport`
//! carries nonzero wall-clock for every phase.
//!
//! Environment knobs (see `testing::PropConfig::from_env`):
//! `NWGRAPH_PROP_SEED` pins the base seed (the CI seed matrix);
//! `NWGRAPH_PROP_CASES` shrinks case counts for fast local runs.

use nwgraph_hpx::algorithms::{bfs, cc, pagerank, pagerank::PrParams, sssp};
use nwgraph_hpx::amt::{FlushPolicy, NetConfig, RuntimeKind, SimConfig};
use nwgraph_hpx::graph::generators::SplitMix64;
use nwgraph_hpx::graph::{generators, DistGraph, PartitionKind};
use nwgraph_hpx::testing::{forall, gen, PropConfig};

fn sim_det() -> SimConfig {
    SimConfig::deterministic(NetConfig::default())
}

fn threads_det() -> SimConfig {
    SimConfig { runtime: RuntimeKind::Threads, ..sim_det() }
}

fn cfg(cases: u32) -> PropConfig {
    PropConfig::from_env(cases, 0x7EEAD5, 28)
}

/// The ISSUE's locality sweep: every count must agree, including the
/// degenerate single-thread runtime.
const LOCALITIES: [u32; 3] = [1, 2, 4];

/// Same policy corners as the engine suite, so the threaded delivery path
/// races the timer-flush and ack-driven tuner too.
fn gen_policy(rng: &mut SplitMix64) -> FlushPolicy {
    match rng.below(7) {
        0 => FlushPolicy::Unbatched,
        1 => FlushPolicy::Items(1 + rng.below(64) as usize),
        2 => FlushPolicy::Bytes(8 + rng.below(1024) as usize),
        3 => FlushPolicy::Adaptive,
        4 => FlushPolicy::TimeWindow(rng.below(30)),
        5 => FlushPolicy::LatencyAdaptive,
        _ => FlushPolicy::Manual,
    }
}

#[test]
fn prop_bfs_levels_identical_on_sim_and_threads() {
    forall(
        &cfg(10),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let root = rng.below(g.n() as u64) as u32;
            (g, root, gen_policy(rng))
        },
        |(g, root, policy)| {
            let want = bfs::sequential::distances(g, *root);
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    let s = bfs::run_async_with(&dist, *root, *policy, sim_det());
                    let t = bfs::run_async_with(&dist, *root, *policy, threads_det());
                    bfs::validate_parents(g, *root, &t.parents)?;
                    let (ls, lt) = (
                        bfs::tree_levels(*root, &s.parents),
                        bfs::tree_levels(*root, &t.parents),
                    );
                    if ls != lt || lt != want {
                        return Err(format!("bfs {kind:?} p={p} {policy:?}: levels diverge"));
                    }
                    if !(t.report.wall_us > 0.0) {
                        return Err(format!("bfs {kind:?} p={p}: threads wall_us == 0"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sssp_distances_agree_on_sim_and_threads() {
    forall(
        &cfg(8),
        |rng, size| {
            let g = gen::ugraph(rng, size);
            let gw = generators::with_random_weights(&g, 0.5, 9.5, rng.next_u64());
            let root = rng.below(gw.n() as u64) as u32;
            (gw, root, gen_policy(rng))
        },
        |(gw, root, policy)| {
            let want = sssp::dijkstra(gw, *root);
            // Label correction converges to the unique shortest-distance
            // fixed point, but tied paths can round differently under
            // different arrival orders — so both runtimes are held to the
            // engine's oracle tolerance, and to each other at the same bar.
            let close = |a: f32, b: f32| {
                (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3
            };
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(gw, kind.build(gw, p));
                    let s = sssp::run_async_with(gw, &dist, *root, *policy, sim_det());
                    let t = sssp::run_async_with(gw, &dist, *root, *policy, threads_det());
                    for v in 0..gw.n() {
                        if !close(s.dist[v], want[v])
                            || !close(t.dist[v], want[v])
                            || !close(s.dist[v], t.dist[v])
                        {
                            return Err(format!(
                                "sssp {kind:?} p={p} {policy:?} v={v}: sim {} threads {} want {}",
                                s.dist[v], t.dist[v], want[v]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pagerank_ranks_agree_on_sim_and_threads() {
    let params = PrParams { alpha: 0.85, iterations: 8 };
    forall(
        &cfg(8),
        |rng, size| (gen::digraph(rng, size), gen_policy(rng)),
        |(g, policy)| {
            let want = pagerank::sequential::pagerank(g, params);
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    let s = pagerank::run_async(&dist, params, *policy, sim_det());
                    let t = pagerank::run_async(&dist, params, *policy, threads_det());
                    for (name, r) in [("sim", &s), ("threads", &t)] {
                        let diff = pagerank::max_abs_diff(&r.ranks, &want);
                        if diff > 1e-4 {
                            return Err(format!(
                                "pagerank {name} {kind:?} p={p} {policy:?}: oracle diff {diff}"
                            ));
                        }
                    }
                    let cross = pagerank::max_abs_diff(&s.ranks, &t.ranks);
                    if cross > 1e-4 {
                        return Err(format!(
                            "pagerank {kind:?} p={p} {policy:?}: sim vs threads diff {cross}"
                        ));
                    }
                    // The iteration barriers must survive the substrate
                    // swap: same count, and each threaded phase took time.
                    if t.report.barriers != s.report.barriers {
                        return Err(format!(
                            "pagerank {kind:?} p={p}: {} barriers on threads, {} on sim",
                            t.report.barriers, s.report.barriers
                        ));
                    }
                    if t.report.phase_wall_us.iter().any(|&w| w <= 0.0) {
                        return Err(format!(
                            "pagerank {kind:?} p={p}: zero-width threaded phase {:?}",
                            t.report.phase_wall_us
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cc_labels_identical_on_sim_and_threads() {
    forall(
        &cfg(10),
        |rng, size| (gen::ugraph(rng, size), gen_policy(rng)),
        |(g, policy)| {
            let want = cc::union_find(g);
            for kind in PartitionKind::all() {
                for p in LOCALITIES {
                    let dist = DistGraph::build_with(g, kind.build(g, p));
                    for (name, labels) in [
                        ("bsp", cc::run(&dist, threads_det()).labels),
                        ("async", cc::run_async(&dist, *policy, threads_det()).labels),
                    ] {
                        if labels != want {
                            return Err(format!(
                                "cc {name} {kind:?} p={p} {policy:?}: threaded labels diverge"
                            ));
                        }
                    }
                    if cc::run_async(&dist, *policy, sim_det()).labels != want {
                        return Err(format!("cc async {kind:?} p={p}: sim labels diverge"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn threaded_results_are_repeat_deterministic() {
    // Loom-free stress: thread *schedules* vary run to run, but the
    // integer-valued fixed points (BFS levels, CC labels) are
    // schedule-independent, so repeated threaded runs must return
    // identical results. SSSP distances are float min-folds over tied
    // paths, so repeats are held to the oracle tolerance instead.
    let seed = cfg(1).seed; // honors NWGRAPH_PROP_SEED via from_env
    let g = generators::kron(8, 8, seed);
    let dist = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 4));

    let levels0 = bfs::tree_levels(
        0,
        &bfs::run_async_with(&dist, 0, FlushPolicy::LatencyAdaptive, threads_det()).parents,
    );
    let labels0 = cc::run_async(&dist, FlushPolicy::Adaptive, threads_det()).labels;
    let gw = generators::with_random_weights(&g, 1.0, 10.0, seed + 1);
    let distw = DistGraph::build_with(&gw, PartitionKind::VertexCut.build(&gw, 4));
    let delta = sssp::auto_delta(&gw);
    let dist0 =
        sssp::run_delta_with(&gw, &distw, 0, delta, FlushPolicy::Adaptive, threads_det()).dist;

    for rep in 0..4 {
        let levels = bfs::tree_levels(
            0,
            &bfs::run_async_with(&dist, 0, FlushPolicy::LatencyAdaptive, threads_det())
                .parents,
        );
        assert_eq!(levels, levels0, "rep {rep}: BFS levels changed across threaded runs");
        let labels = cc::run_async(&dist, FlushPolicy::Adaptive, threads_det()).labels;
        assert_eq!(labels, labels0, "rep {rep}: CC labels changed across threaded runs");
        let d = sssp::run_delta_with(&gw, &distw, 0, delta, FlushPolicy::Adaptive, threads_det())
            .dist;
        for v in 0..gw.n() {
            let (a, b) = (d[v], dist0[v]);
            assert!(
                (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3,
                "rep {rep}: sssp dist[{v}] drifted: {a} vs {b}"
            );
        }
    }
}

#[test]
fn kron10_acceptance_threads_match_sim_with_nonzero_phase_wall_clock() {
    // PR acceptance pin (release CI runs this suite): on the benchmark
    // kron10@8 shape, all four algorithms agree across runtimes and the
    // threaded SimReport carries real wall-clock — end-to-end and for
    // every barrier-delimited phase.
    let seed = cfg(1).seed; // honors NWGRAPH_PROP_SEED via from_env
    let g = generators::kron(10, 8, seed);
    let dist = DistGraph::build_with(&g, PartitionKind::VertexCut.build(&g, 8));
    assert!(dist.has_mirrors(), "kron10@8 vertex cut should mirror");

    let check_wall = |name: &str, r: &nwgraph_hpx::amt::SimReport| {
        assert!(r.wall_us > 0.0, "{name}: threaded wall_us not measured");
        assert_eq!(
            r.makespan_us, r.wall_us,
            "{name}: threaded makespan must BE the wall clock"
        );
        assert_eq!(
            r.phase_wall_us.len() as u64,
            r.barriers + 1,
            "{name}: {} barriers should cut {} phases, got {:?}",
            r.barriers,
            r.barriers + 1,
            r.phase_wall_us
        );
        for (i, &w) in r.phase_wall_us.iter().enumerate() {
            assert!(w > 0.0, "{name}: phase {i} reported zero wall-clock");
        }
    };

    // BFS: levels identical.
    let s = bfs::run_async_with(&dist, 0, FlushPolicy::Adaptive, sim_det());
    let t = bfs::run_async_with(&dist, 0, FlushPolicy::Adaptive, threads_det());
    assert_eq!(
        bfs::tree_levels(0, &s.parents),
        bfs::tree_levels(0, &t.parents),
        "bfs: levels diverge across runtimes"
    );
    check_wall("bfs", &t.report);

    // CC: labels identical.
    let s = cc::run_async(&dist, FlushPolicy::Adaptive, sim_det());
    let t = cc::run_async(&dist, FlushPolicy::Adaptive, threads_det());
    assert_eq!(s.labels, t.labels, "cc: labels diverge across runtimes");
    check_wall("cc", &t.report);

    // PageRank: ranks within the oracle tolerance, barriers preserved.
    let params = PrParams { alpha: 0.85, iterations: 10 };
    let s = pagerank::run_async(&dist, params, FlushPolicy::Adaptive, sim_det());
    let t = pagerank::run_async(&dist, params, FlushPolicy::Adaptive, threads_det());
    let diff = pagerank::max_abs_diff(&s.ranks, &t.ranks);
    assert!(diff <= 1e-4, "pagerank: sim vs threads diff {diff}");
    assert_eq!(s.report.barriers, t.report.barriers, "pagerank: barrier count diverges");
    check_wall("pagerank", &t.report);

    // SSSP (delta engine): distances within the oracle tolerance.
    let gw = generators::with_random_weights(&g, 1.0, 10.0, seed + 1);
    let distw = DistGraph::build_with(&gw, PartitionKind::VertexCut.build(&gw, 8));
    let delta = sssp::auto_delta(&gw);
    let s = sssp::run_delta_with(&gw, &distw, 0, delta, FlushPolicy::Adaptive, sim_det());
    let t = sssp::run_delta_with(&gw, &distw, 0, delta, FlushPolicy::Adaptive, threads_det());
    for v in 0..gw.n() {
        let (a, b) = (s.dist[v], t.dist[v]);
        assert!(
            (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3,
            "sssp dist[{v}]: sim {a} vs threads {b}"
        );
    }
    check_wall("sssp", &t.report);
}
