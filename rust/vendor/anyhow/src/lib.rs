//! Vendored, offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this crate provides
//! the (small) subset of anyhow's API the workspace actually uses:
//! [`Error`], [`Result`], and the [`anyhow!`], [`bail!`] and [`ensure!`]
//! macros. Like the real crate, [`Error`] deliberately does **not**
//! implement `std::error::Error` so the blanket `From` conversion below
//! can exist; `?` works on any `std::error::Error + Send + Sync` source.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error, convertible from any standard error.
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error(Box::new(MessageError(message)))
    }

    /// Wrap a standard error.
    pub fn new<E>(error: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error(Box::new(error))
    }

    /// Borrow the underlying error.
    pub fn as_dyn(&self) -> &(dyn StdError + 'static) {
        &*self.0
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> StdError for MessageError<M> {}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($tt)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse()?; // std error converts via the blanket From
        ensure!(v < 100, "{v} too large");
        Ok(v)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
        assert_eq!(parse("500").unwrap_err().to_string(), "500 too large");
    }

    #[test]
    fn bail_and_formatting() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("failed with {}", 7);
            }
            Ok(())
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "failed with 7");
    }

    #[test]
    fn debug_shows_message() {
        let e = anyhow!("boom");
        assert_eq!(format!("{e:?}"), "boom");
    }
}
